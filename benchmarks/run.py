"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Graphs are R-MAT stand-ins
shaped like the paper's Table 4.1 datasets (scaled to CPU budgets; pass
--scale to change).  Tables covered:

  * Table 4.6/4.7 (sequential optimization ladder) -> bench_census_versions
  * Table 4.8/4.12 (load-balance strategies)       -> bench_balance
  * Table 4.13/Fig 4.8 (strong scaling)            -> bench_scaling
  * Table 3.1 'Synch.' row (decoupled vs shared)   -> bench_accumulators
  * §5 GPU kernel + Table 5.11 (shared-mem census) -> bench_kernel
  * LM-side step benches (framework)               -> bench_lm_smoke
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def _timeit(fn, *args, reps=3, warmup=1):
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    out = None
    for _ in range(reps):
        out = fn(*args)
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6  # us


def bench_census_versions(scale: float):
    """Paper Tables 4.6/4.7: the optimization ladder, TPU-translated.

    v0.4: precomputed dyad code (4 probes/candidate) = production path;
    v0.1-like: dyad code re-derived per candidate (6 probes);
    v0.5 analogue: degree bucketing in the Pallas kernel path.
    """
    import math
    from repro.core import generators
    from repro.core.census import (canonical_dyads, make_census_batch_fn,
                                   pad_dyads)

    g = generators.paper_profile("slashdot", scale_down=64 / scale)
    u, v = canonical_dyads(g)
    uu, vv, valid = pad_dyads(u, v, 256)

    K = max(1, g.max_deg)
    iters = max(1, math.ceil(math.log2(max(g.max_deg, g.max_out_deg, 1) + 1))) + 1

    def scan_fn(batch_fn):
        @jax.jit
        def run(arrays, n, us, vs, va):
            steps = us.shape[0] // 256

            def body(c, xs):
                a, b, m = xs
                return c, batch_fn(arrays, n, a, b, m)

            _, parts = jax.lax.scan(
                body, 0, (us.reshape(steps, 256), vs.reshape(steps, 256),
                          va.reshape(steps, 256)))
            return parts

        return run

    four = scan_fn(make_census_batch_fn(K, iters))
    six = scan_fn(make_census_batch_fn(K, iters, six_probe=True))
    args = (g.arrays, jnp.int32(g.n), jnp.asarray(uu), jnp.asarray(vv),
            jnp.asarray(valid))
    t_modern = _timeit(lambda: four(*args))
    t_naive = _timeit(lambda: six(*args))
    print(f"census_v04_precomputed_code,{t_modern:.0f},speedup_vs_6probe="
          f"{t_naive / t_modern:.2f}x")

    from repro.kernels.ops import triad_census_kernel
    t_flat = _timeit(lambda: triad_census_kernel(
        g, block=32, buckets=(max(g.max_deg, 1),)), reps=1)
    t_bucket = _timeit(lambda: triad_census_kernel(
        g, block=32, buckets=(32, 128, 512)), reps=1)
    print(f"census_kernel_bucketed,{t_bucket:.0f},speedup_vs_flat="
          f"{t_flat / max(t_bucket, 1e-9):.2f}x")


def bench_balance(scale: float):
    """Paper Tables 4.8/4.12: strategy quality + packing cost."""
    from repro.core import exact_s_sizes, generators, pack_tasks
    from repro.core.census import canonical_dyads

    g = generators.paper_profile("slashdot", scale_down=64 / scale)
    for strat in ("greedy_sequential", "sorted_snake", "greedy_lpt"):
        for wm in ("canonical_uniform", "canonical_nonuniform"):
            t0 = time.perf_counter()
            t = pack_tasks(g, 64, weight_model=wm, strategy=strat)
            dt = (time.perf_counter() - t0) * 1e6
            print(f"balance_{strat}_{wm},{dt:.0f},imbalance={t.imbalance:.4f}")
    u, v = canonical_dyads(g)
    m = (min(len(u), 20_000) // 1024) * 1024
    t_host = _timeit(lambda: exact_s_sizes(g, u[:m], v[:m], device=False),
                     reps=1, warmup=0)
    t_dev = _timeit(lambda: exact_s_sizes(g, u[:m], v[:m], device=True),
                    reps=2, warmup=1)
    print(f"exact_s_host_sequential,{t_host:.0f},paper_v06_bottleneck")
    print(f"exact_s_device_vectorized,{t_dev:.0f},speedup="
          f"{t_host / max(t_dev, 1e-9):.1f}x")


def bench_accumulators(scale: float):
    """Table 3.1 'Synch.' row: decoupled per-worker census arrays vs a
    single shared array updated serially (the TPU stand-in for atomics)."""
    from repro.core import generators
    from repro.core.census import canonical_dyads, make_census_fn, pad_dyads

    g = generators.paper_profile("slashdot", scale_down=64 / scale)
    u, v = canonical_dyads(g)
    uu, vv, valid = pad_dyads(u, v, 256)
    fn = make_census_fn(g, batch=256)
    args = (g.arrays, jnp.int32(g.n), jnp.asarray(uu), jnp.asarray(vv),
            jnp.asarray(valid))
    t_dec = _timeit(lambda: np.asarray(fn(*args)).sum(0))

    @jax.jit
    def shared(arrays, n, us, vs, va):
        parts = fn(arrays, n, us, vs, va)

        def body(c, p):
            return c.at[:].add(p), None

        out, _ = jax.lax.scan(body, jnp.zeros(16, jnp.int32), parts)
        return out

    t_sh = _timeit(lambda: shared(*args))
    print(f"census_decoupled_accumulators,{t_dec:.0f},vs_shared="
          f"{t_sh / max(t_dec, 1e-9):.2f}x")


def bench_scaling(scale: float):
    """Fig 4.8 strong scaling: modeled per-shard work vs worker count."""
    from repro.core import generators, pack_tasks

    g = generators.paper_profile("amazon", scale_down=64 / scale)
    base = None
    for T in (1, 2, 4, 8, 16, 32, 64, 128):
        t = pack_tasks(g, T, strategy="sorted_snake")
        work = t.weights.max()
        base = base or work
        print(f"scaling_T{T},{work:.0f},speedup={base / work:.2f}x"
              f",imbalance={t.imbalance:.3f}")


def bench_kernel(scale: float):
    """§5.4/Table 5.11: the census kernel (VMEM census per block ~ GPU
    shared-memory census per thread block) vs the XLA binary-search path.
    NOTE: kernel timings on CPU are interpret-mode (python) — structural
    only; real comparisons need a TPU."""
    from repro.core import generators
    from repro.engine import CensusConfig, compile_census

    g = generators.paper_profile("eatSR", scale_down=64 / scale)
    xla = compile_census(g, CensusConfig(backend="xla", batch=256))
    krn = compile_census(g, CensusConfig(backend="pallas", batch=32,
                                         buckets=(64, 256)))
    t_xla = _timeit(lambda: xla.run(g).counts, reps=1)
    t_krn = _timeit(lambda: krn.run(g).counts, reps=1)
    print(f"census_xla_binary_search,{t_xla:.0f},cpu_wallclock")
    print(f"census_pallas_kernel,{t_krn:.0f},interpret_mode_structural_only")


def bench_engine_cache(scale: float):
    """The serving metric the north-star cares about: cold compile+run vs
    warm plan-cache-hit census latency on a same-shape graph."""
    from repro.core import generators
    from repro.engine import (CensusConfig, GraphMeta, clear_plan_cache,
                              compile_census, plan_cache_stats)

    g = generators.paper_profile("slashdot", scale_down=128 / scale)
    g_warm = generators.paper_profile("slashdot", scale_down=128 / scale,
                                      seed=1)
    if GraphMeta.from_graph(g_warm) != GraphMeta.from_graph(g):
        g_warm = g  # different realization crossed a pow2 bucket: reuse g
    cfg = CensusConfig(backend="xla", batch=256)

    clear_plan_cache()
    t0 = time.perf_counter()
    plan = compile_census(g, cfg)
    plan.run(g)
    t_cold = (time.perf_counter() - t0) * 1e6

    t0 = time.perf_counter()
    plan2 = compile_census(g_warm, cfg)  # same shape buckets -> cache hit
    plan2.run(g_warm)
    t_warm = (time.perf_counter() - t0) * 1e6

    stats = plan_cache_stats()
    assert plan2 is plan and stats["hits"] >= 1, stats
    print(f"engine_census_cold_compile,{t_cold:.0f},traces={plan.stats['traces']}")
    print(f"engine_census_warm_cache_hit,{t_warm:.0f},speedup="
          f"{t_cold / max(t_warm, 1e-9):.2f}x")


def _census_cold(g, cfg):
    """Compile + first run; returns (plan, cold wall seconds)."""
    from repro.engine import compile_census

    t0 = time.perf_counter()
    plan = compile_census(g, cfg)
    plan.run(g)
    return plan, time.perf_counter() - t0


def _census_warm(plan, g):
    """One timed warm run + per-run chunk/sync stats."""
    c0, s0 = plan.stats["chunks"], plan.stats["host_syncs"]
    t0 = time.perf_counter()
    plan.run(g)
    dt = time.perf_counter() - t0
    return dt, dict(chunks_per_run=plan.stats["chunks"] - c0,
                    host_syncs_per_run=plan.stats["host_syncs"] - s0,
                    traces=plan.stats["traces"])


def bench_device_pipeline(scale: float, *, sync_baseline: bool = False,
                          smoke: bool = False,
                          out: str = "BENCH_census.json"):
    """The device-resident streaming pipeline, tracked as machine-readable
    JSON (``BENCH_census.json``) from this PR onward.

    Per (graph, backend): cold/warm wall time, chunks and device→host sync
    count per run (the one-transfer-per-run claim, measured), dyads/sec.
    ``--sync-baseline`` additionally runs the synchronous PR-1 data path
    (``device_accum=False``) on the same plans for an A/B speedup.
    """
    from repro.core import generators
    from repro.engine import CensusConfig, clear_plan_cache

    if smoke:
        cases = [
            ("rmat8", generators.rmat(8, edge_factor=4, seed=0),
             ("xla", "distributed")),
            ("rmat6", generators.rmat(6, edge_factor=4, seed=0),
             ("pallas",)),
        ]
    else:
        cases = [
            # largest generated graph: sparse ER is the memory-bound regime
            # (small K, many chunks) where the data path — not the census
            # compute — is on the clock, i.e. the paper's actual bottleneck
            ("er_sparse", generators.erdos_renyi(int(30000 * scale),
                                                 int(60000 * scale), seed=0),
             ("xla", "distributed", "pallas")),
            # compute-bound power-law profile for contrast
            ("slashdot", generators.paper_profile("slashdot",
                                                  scale_down=64 / scale),
             ("xla", "distributed")),
            # pallas runs interpret-mode (python) off-TPU: smaller profile
            ("eatSR", generators.paper_profile("eatSR",
                                               scale_down=256 / scale),
             ("pallas",)),
        ]
    # chunk well below the dyad counts so runs stream multiple chunks —
    # the sync-count metric then shows O(chunks) transfers for the
    # baseline vs O(1) for the device-resident path.
    chunk = 256 if smoke else 2048
    results = []
    for name, g, backends in cases:
        for backend in backends:
            clear_plan_cache()
            # also drop module-level jit caches (enumerate/sort/_pallas_
            # chunk survive clear_plan_cache): later same-shape cases
            # would otherwise report understated cold_s in the JSON.
            jax.clear_caches()
            cfg = CensusConfig(backend=backend, batch=256,
                               chunk_dyads=chunk)
            reps = 2 if backend == "pallas" else 5
            plan, cold = _census_cold(g, cfg)
            syn_plan = None
            if sync_baseline:
                syn_plan, syn_cold = _census_cold(
                    g, CensusConfig(backend=backend, batch=256,
                                    chunk_dyads=chunk, device_accum=False))
            # interleave warm reps of both paths so machine drift hits
            # them equally; report min-of-reps.
            warm = syn_warm = float("inf")
            for _ in range(reps):
                dt, dev = _census_warm(plan, g)
                warm = min(warm, dt)
                if syn_plan is not None:
                    dt, syn = _census_warm(syn_plan, g)
                    syn_warm = min(syn_warm, dt)
            row = dict(graph=name, backend=backend, n=g.n, m=g.m,
                       dyads=g.n_dyads, device_path=plan.device_path,
                       dyads_per_sec=g.n_dyads / max(warm, 1e-9),
                       cold_s=cold, warm_s=warm, **dev)
            if syn_plan is not None:
                row["sync_baseline"] = dict(cold_s=syn_cold, warm_s=syn_warm,
                                            **syn)
                row["speedup_vs_sync"] = syn_warm / max(warm, 1e-9)
            results.append(row)
            extra = (f",speedup_vs_sync={row['speedup_vs_sync']:.2f}x"
                     if sync_baseline else "")
            print(f"census_pipeline_{name}_{backend},"
                  f"{row['warm_s'] * 1e6:.0f},syncs_per_run="
                  f"{row['host_syncs_per_run']}"
                  f",chunks={row['chunks_per_run']}{extra}")
    _merge_json(out, schema=1, smoke=smoke,
                jax_backend=jax.default_backend(), results=results)
    print(f"# wrote {out}")


def _merge_json(out: str, **sections) -> None:
    """Update ``out`` in place, preserving sections other benches wrote
    (the pipeline bench must not drop 'serve' and vice versa)."""
    try:
        with open(out) as f:
            payload = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        payload = {}
    payload.update(sections)
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)


def _same_bucket_fleet(make, n_want: int, k=None):
    """Generate graphs until ``n_want`` share one GraphMeta bucket."""
    from repro.engine import GraphMeta

    groups: dict = {}
    for seed in range(4 * n_want):
        g = make(seed)
        groups.setdefault(GraphMeta.from_graph(g, k=k), []).append(g)
        best = max(groups.values(), key=len)
        if len(best) >= n_want:
            return best[:n_want]
    return max(groups.values(), key=len)


def bench_serve(scale: float, *, smoke: bool = False,
                out: str = "BENCH_census.json"):
    """``--serve``: fleet requests/sec, batched service vs sequential runs.

    The serving claim the tentpole makes, measured: a fleet of small
    same-bucket graphs (the common SNA request pattern — per-ego or
    per-community subgraphs, not one giant graph) through
    ``CensusService`` (one vmapped dispatch schedule + one transfer per
    batch) vs one ``plan.run`` per request on the same warm plan.  Also
    runs a mixed rmat/erdos_renyi fleet spanning several buckets.
    Batching pays where per-request dispatch overhead rivals the census
    compute — i.e. small graphs; on large graphs the vmapped unit
    degenerates to the same device work and the speedup fades to ~1x.
    Results merge into ``BENCH_census.json`` under ``"serve"``.
    """
    from repro.core import generators
    from repro.engine import CensusConfig, clear_plan_cache, compile_census
    from repro.serve import CensusService, ServiceConfig

    cfg = CensusConfig(backend="xla", batch=64, chunk_dyads=64)
    if smoke:
        same = _same_bucket_fleet(
            lambda s: generators.rmat(5, edge_factor=2, seed=s), 16, k=cfg.k)
        mixed = same[:8] + [generators.erdos_renyi(48, 96, seed=s)
                            for s in range(8)]
    else:
        same = _same_bucket_fleet(
            lambda s: generators.rmat(6, edge_factor=2, seed=s), 64, k=cfg.k)
        mixed = same[:32] + [generators.erdos_renyi(128, 256, seed=s)
                             for s in range(32)]
    max_batch = 8

    def sequential(fleet):
        for g in fleet:
            compile_census(g, cfg).run(g)

    def batched(fleet):
        svc = CensusService(ServiceConfig(max_batch=max_batch,
                                          max_wait_requests=len(fleet),
                                          census=cfg))
        svc.run_fleet(fleet)
        return svc

    rows = []
    for name, fleet in (("same_bucket", same), ("mixed", mixed)):
        clear_plan_cache()
        # warm both paths: compiles (incl. the vmapped batch widths the
        # timed runs will use) land outside the timed region.
        sequential(fleet)
        svc = batched(fleet)
        # min-of-reps, interleaved: this container is noisy-neighbor
        # territory, and a single slow rep on either side would turn the
        # requests/sec ratio into machine-load measurement.
        t_seq = t_bat = float("inf")
        for _ in range(6 if smoke else 4):
            t0 = time.perf_counter()
            sequential(fleet)
            t_seq = min(t_seq, time.perf_counter() - t0)
            t0 = time.perf_counter()
            svc = batched(fleet)
            t_bat = min(t_bat, time.perf_counter() - t0)
        st = svc.stats()
        row = dict(fleet=name, n_requests=len(fleet),
                   buckets=len(st["buckets"]), max_batch=max_batch,
                   mean_batch=st["mean_batch"],
                   sequential_rps=len(fleet) / max(t_seq, 1e-9),
                   batched_rps=len(fleet) / max(t_bat, 1e-9))
        row["speedup"] = row["batched_rps"] / max(row["sequential_rps"], 1e-9)
        rows.append(row)
        print(f"census_serve_{name},{t_bat / len(fleet) * 1e6:.0f},"
              f"batched_rps={row['batched_rps']:.0f}"
              f",sequential_rps={row['sequential_rps']:.0f}"
              f",speedup={row['speedup']:.2f}x"
              f",mean_batch={row['mean_batch']:.1f}")
    _merge_json(out, schema=1, jax_backend=jax.default_backend(),
                serve=dict(smoke=smoke, results=rows))
    print(f"# wrote {out}")


def bench_ops(scale: float, *, smoke: bool = False,
              out: str = "BENCH_census.json"):
    """``--ops``: per-op and fused-vs-separate throughput (the GraphOp
    layer's claim, measured).

    Times each registered analytic as its own pass, then all of them as
    ONE fused pass over the same dyad stream; since the workload is
    memory-bound (the traversal dominates), the fused pass should beat
    the sum of separate passes.  Results merge into ``BENCH_census.json``
    under ``"ops"``: per-op warm time + host syncs, fused time, and the
    ``fused_speedup`` ratio.
    """
    from repro.core import generators
    from repro.engine import EngineConfig, clear_plan_cache, compile

    names = ("triad_census", "dyad_census", "degree_stats",
             "triadic_profile")
    if smoke:
        g = generators.rmat(8, edge_factor=4, seed=0)
        cfg = EngineConfig(backend="xla", batch=256, chunk_dyads=512)
        reps = 5
    else:
        g = generators.paper_profile("slashdot", scale_down=64 / scale)
        cfg = EngineConfig(backend="xla", batch=256, chunk_dyads=2048)
        reps = 4
    clear_plan_cache()
    solo_plans = {nm: compile(g, (nm,), cfg) for nm in names}
    fused_plan = compile(g, names, cfg)
    for p in (*solo_plans.values(), fused_plan):  # warm every trace
        p.run(g)

    def timed(fn):
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    per_op = []
    separate_s = 0.0
    for nm, plan in solo_plans.items():
        s0 = plan.stats["host_syncs"]
        r0 = plan.stats["runs"]
        warm = timed(lambda p=plan: p.run(g))
        per_op.append(dict(
            op=nm, warm_s=warm, dyads_per_sec=g.n_dyads / max(warm, 1e-9),
            host_syncs_per_run=((plan.stats["host_syncs"] - s0)
                                / (plan.stats["runs"] - r0))))
        separate_s += warm
        print(f"census_op_{nm},{warm * 1e6:.0f},"
              f"syncs_per_run={per_op[-1]['host_syncs_per_run']:.0f}")
    s0 = fused_plan.stats["host_syncs"]
    r0 = fused_plan.stats["runs"]
    fused_s = timed(lambda: fused_plan.run(g))
    fused_syncs = ((fused_plan.stats["host_syncs"] - s0)
                   / (fused_plan.stats["runs"] - r0))
    speedup = separate_s / max(fused_s, 1e-9)
    print(f"census_ops_fused_{len(names)}way,{fused_s * 1e6:.0f},"
          f"separate_s={separate_s * 1e6:.0f}us"
          f",fused_speedup={speedup:.2f}x,syncs_per_run={fused_syncs:.0f}")
    _merge_json(out, schema=1, jax_backend=jax.default_backend(),
                ops=dict(smoke=smoke, graph=dict(n=g.n, m=g.m,
                                                 dyads=g.n_dyads),
                         backend=cfg.backend, per_op=per_op,
                         fused=dict(ops=list(names), warm_s=fused_s,
                                    host_syncs_per_run=fused_syncs,
                                    separate_s=separate_s,
                                    fused_speedup=speedup)))
    print(f"# wrote {out}")


def bench_executor(scale: float, *, smoke: bool = False,
                   out: str = "BENCH_census.json"):
    """``--executor``: static-vs-dynamic schedule and 1-vs-N device
    throughput (the executor layer's claim, measured).

    Runs the census on a degree-skewed R-MAT graph under (a) the default
    static single-device schedule, (b) the dynamic cost-model schedule on
    one device (degree-aware chunk boundaries alone), and (c) the dynamic
    schedule work-queued over every visible device.  The host-platform
    device count must be fixed before jax initializes, so when only one
    device is visible this bench re-execs itself under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (CI sets the
    flag up front).  Results merge into ``BENCH_census.json`` under
    ``"executor"``, including ``dynamic_speedup`` — pool-dynamic vs
    static-single throughput — and the per-device chunk spread.
    """
    import os

    n_dev = len(jax.devices())
    # the forced-host-device flag only multiplies CPU devices and must be
    # set before jax initializes, so re-exec exactly once and only where
    # it can help — a non-CPU backend (one GPU/TPU visible) would see the
    # same single device again and loop forever.
    if (n_dev < 2 and jax.default_backend() == "cpu"
            and not os.environ.get("_REPRO_EXECUTOR_REEXEC")):
        import subprocess
        import sys
        env = {**os.environ, "_REPRO_EXECUTOR_REEXEC": "1"}
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=8"
                            ).strip()
        cmd = [sys.executable, __file__, "--executor", "--scale", str(scale),
               "--out", out] + (["--smoke"] if smoke else [])
        r = subprocess.run(cmd, env=env)
        if r.returncode:
            raise RuntimeError(
                f"executor bench subprocess failed ({r.returncode})")
        return  # child merged its 'executor' section into the JSON

    from repro.core import generators
    from repro.engine import EngineConfig, clear_plan_cache, compile

    if smoke:
        g = generators.rmat(10, edge_factor=8, seed=0)
        chunk, reps = 512, 3
    else:
        g = generators.rmat(13, edge_factor=8, seed=0)
        chunk, reps = 2048, 4
    # on a host where the pool cannot grow (single non-CPU device), the
    # N-device case would duplicate dynamic-1dev — drop it.
    cases = [("static", 1), ("dynamic", 1)]
    if n_dev > 1:
        cases.append(("dynamic", n_dev))
    clear_plan_cache()
    plans = []
    baseline = None
    for schedule, nd in cases:
        cfg = EngineConfig(backend="xla", batch=256, chunk_dyads=chunk,
                           schedule=schedule, n_executor_devices=nd)
        plan = compile(g, ("triad_census",), cfg)
        ref = plan.run(g)["triad_census"].counts  # warm every device replica
        baseline = ref if baseline is None else baseline
        assert (ref == baseline).all()  # bit-identity across schedules
        plans.append(plan)
    # interleave warm reps across cases so machine drift hits them
    # equally (this container is noisy-neighbor territory); min-of-reps.
    warms = [float("inf")] * len(plans)
    c0s = [p.stats["chunks"] for p in plans]
    for _ in range(reps):
        for i, plan in enumerate(plans):
            t0 = time.perf_counter()
            plan.run(g)
            warms[i] = min(warms[i], time.perf_counter() - t0)
    rows = []
    for (schedule, _), plan, warm, c0 in zip(cases, plans, warms, c0s):
        row = dict(schedule=schedule, n_devices=plan.executor.n_devices,
                   warm_s=warm, dyads_per_sec=g.n_dyads / max(warm, 1e-9),
                   chunks_per_run=(plan.stats["chunks"] - c0) // reps,
                   device_chunks={str(d): c for d, c in
                                  plan.stats["device_chunks"].items()})
        rows.append(row)
        print(f"census_executor_{schedule}_{row['n_devices']}dev,"
              f"{warm * 1e6:.0f},dyads_per_sec={row['dyads_per_sec']:.0f}"
              f",chunks={row['chunks_per_run']}")
    speedup = rows[0]["warm_s"] / max(rows[-1]["warm_s"], 1e-9)
    print(f"census_executor_dynamic_speedup,0,"
          f"dynamic_{n_dev}dev_vs_static_1dev={speedup:.2f}x")
    _merge_json(out, schema=1, jax_backend=jax.default_backend(),
                executor=dict(smoke=smoke, n_devices_visible=n_dev,
                              graph=dict(n=g.n, m=g.m, dyads=g.n_dyads),
                              results=rows, dynamic_speedup=speedup))
    print(f"# wrote {out}")


def bench_delta(scale: float, *, smoke: bool = False,
                out: str = "BENCH_census.json"):
    """``--delta``: incremental delta census vs full recompute.

    Mutates the largest bench graph with edge deltas of growing footprint
    and times ``plan.apply_delta`` (subset passes over old + new affected
    dyads, one sync) against ``plan.run_raw`` on the mutated graph (both
    warm).  Then drives a subscribed ``CensusService`` session through a
    stream of small mutations and compares mutations/sec against
    resubmitting each mutated graph as a fresh stateless request.
    Results merge into ``BENCH_census.json`` under ``"delta"``:
    per-footprint rows with ``affected_fraction`` and ``speedup``, plus
    the session-vs-resubmission rate.
    """
    from repro.core import generators
    from repro.core.delta import GraphDelta, apply_delta_csr
    from repro.engine import EngineConfig, clear_plan_cache, compile
    from repro.serve import CensusService, ServiceConfig

    if smoke:
        g = generators.rmat(10, edge_factor=8, seed=0)
        chunk, reps, footprints = 512, 3, (4, 32, 256)
    else:
        g = generators.rmat(13, edge_factor=8, seed=0)
        chunk, reps, footprints = 2048, 4, (4, 64, 1024)
    clear_plan_cache()
    cfg = EngineConfig(backend="xla", batch=256, chunk_dyads=chunk,
                       delta_threshold=1.0)  # never fall back: measure it
    plan = compile(g, ("triad_census",), cfg)
    raw = plan.run_raw(g)
    rng = np.random.default_rng(0)

    def footprint_delta(k):
        # k removals of existing arcs + k random additions
        out_ptr = np.asarray(g.arrays.out_ptr)[: g.n + 1]
        dst = np.asarray(g.arrays.out_idx)[: g.m].astype(np.int64)
        src = np.repeat(np.arange(g.n, dtype=np.int64), np.diff(out_ptr))
        sel = rng.choice(g.m, size=min(k, g.m), replace=False)
        return GraphDelta(edges_added=rng.integers(0, g.n, size=(k, 2)),
                          edges_removed=np.stack([src[sel], dst[sel]], 1))

    rows = []
    for k in footprints:
        d = footprint_delta(k)
        g_new = apply_delta_csr(g, d)
        plan.run_raw(g_new)                      # warm the full path
        res = plan.apply_delta(g, d, raw)        # warm the delta path
        assert res.mode == "delta" and (res.raw == plan.run_raw(g_new)).all()
        t_delta = t_full = float("inf")
        for _ in range(reps):                    # interleaved min-of-reps
            t0 = time.perf_counter()
            plan.apply_delta(g, d, raw)
            t_delta = min(t_delta, time.perf_counter() - t0)
            t0 = time.perf_counter()
            plan.run_raw(g_new)
            t_full = min(t_full, time.perf_counter() - t0)
        row = dict(footprint_arcs=int(d.size),
                   affected_fraction=res.affected_fraction,
                   delta_s=t_delta, full_s=t_full,
                   speedup=t_full / max(t_delta, 1e-9))
        rows.append(row)
        print(f"census_delta_{k}arcs,{t_delta * 1e6:.0f},"
              f"affected={row['affected_fraction']:.4f}"
              f",vs_full={row['speedup']:.2f}x")

    # subscribed session stream vs stateless resubmission of each snapshot
    n_mut = 8 if smoke else 16
    deltas = [footprint_delta(4) for _ in range(n_mut)]
    svc = CensusService(ServiceConfig(census=cfg))
    sid = svc.subscribe(g)
    t0 = time.perf_counter()
    for d in deltas:
        svc.mutate(sid, d)
    svc.poll(sid)
    t_sess = time.perf_counter() - t0
    svc.unsubscribe(sid)
    cur = g
    t0 = time.perf_counter()
    for d in deltas:
        cur = apply_delta_csr(cur, d)
        svc.submit(cur)
        svc.flush()
    t_resub = time.perf_counter() - t0
    session = dict(mutations=n_mut,
                   session_mut_per_sec=n_mut / max(t_sess, 1e-9),
                   resubmit_req_per_sec=n_mut / max(t_resub, 1e-9),
                   speedup=t_resub / max(t_sess, 1e-9))
    print(f"census_delta_session,{t_sess / n_mut * 1e6:.0f},"
          f"vs_resubmission={session['speedup']:.2f}x")
    _merge_json(out, schema=1, jax_backend=jax.default_backend(),
                delta=dict(smoke=smoke,
                           graph=dict(n=g.n, m=g.m, dyads=g.n_dyads),
                           results=rows, session=session))
    print(f"# wrote {out}")


def bench_faults(scale: float, *, smoke: bool = False,
                 out: str = "BENCH_census.json"):
    """``--faults``: the robustness tax, measured.

    Times three warm census variants on the same graph: (a) *baseline* —
    an explicitly inert ``FaultPlan`` (injection checks compiled out of
    the dispatch path, the production default), (b) *armed* — a live
    fault plan whose faults can never fire (a dead device index far past
    the pool), paying only the per-dispatch decision hashes, and (c)
    *recovering* — seeded chunk chaos where every selected chunk fails
    once and retries (``fail_attempts=1``), measuring what actual
    recovery costs.  All three produce bit-identical counts in one
    device→host sync.  Results merge into ``BENCH_census.json`` under
    ``"faults"`` with ``armed_overhead_pct`` (the fault-free tax — the
    acceptance bar is < 5%) and ``recovery_tax_pct``.
    """
    from repro.core import generators
    from repro.engine import (EngineConfig, FaultPlan, clear_plan_cache,
                              compile)

    if smoke:
        g = generators.rmat(10, edge_factor=8, seed=0)
        chunk, reps = 512, 5
    else:
        g = generators.rmat(13, edge_factor=8, seed=0)
        chunk, reps = 2048, 6
    cases = [
        ("baseline", FaultPlan()),
        ("armed", FaultPlan(seed=3, device_loss=(99,))),
        ("recovering", FaultPlan(seed=3, chunk_failure_rate=0.25,
                                 fail_attempts=1)),
    ]
    clear_plan_cache()
    plans, baseline = [], None
    for _, fp in cases:
        cfg = EngineConfig(backend="xla", batch=256, chunk_dyads=chunk,
                           fault_plan=fp)
        plan = compile(g, ("triad_census",), cfg)
        ref = plan.run(g)["triad_census"].counts  # warm + correctness
        baseline = ref if baseline is None else baseline
        assert (ref == baseline).all()  # recovery is bit-identical
        assert plan.stats["host_syncs"] == plan.stats["runs"]
        plans.append(plan)
    assert plans[-1].stats["faults"]["retries"] > 0  # chaos actually fired
    warms = [float("inf")] * len(plans)
    for _ in range(reps):  # interleaved min-of-reps (noisy-neighbor box)
        for i, plan in enumerate(plans):
            t0 = time.perf_counter()
            plan.run(g)
            warms[i] = min(warms[i], time.perf_counter() - t0)
    rows = []
    for (name, _), plan, warm in zip(cases, plans, warms):
        row = dict(case=name, warm_s=warm,
                   dyads_per_sec=g.n_dyads / max(warm, 1e-9),
                   retries_per_run=(plan.stats["faults"]["retries"]
                                    // plan.stats["runs"]))
        rows.append(row)
        print(f"census_faults_{name},{warm * 1e6:.0f},"
              f"retries_per_run={row['retries_per_run']}")
    armed_pct = 100.0 * (warms[1] - warms[0]) / max(warms[0], 1e-9)
    tax_pct = 100.0 * (warms[2] - warms[0]) / max(warms[0], 1e-9)
    print(f"census_faults_overhead,0,armed={armed_pct:.1f}%"
          f",recovering={tax_pct:.1f}%")
    _merge_json(out, schema=1, jax_backend=jax.default_backend(),
                faults=dict(smoke=smoke,
                            graph=dict(n=g.n, m=g.m, dyads=g.n_dyads),
                            results=rows, armed_overhead_pct=armed_pct,
                            recovery_tax_pct=tax_pct))
    print(f"# wrote {out}")


def bench_reorder(scale: float, *, smoke: bool = False,
                  out: str = "BENCH_census.json"):
    """``--reorder``: locality-aware relabeling, measured.

    Times the warm census path on a degree-skewed R-MAT graph whose
    vertex labels were adversarially scrambled (a seeded random
    relabeling — R-MAT's natural ids are already hub-clustered, which
    would mask the strategies) under each ``EngineConfig(reorder=)``
    strategy: none, degree, bfs, rcm.  Every strategy's counts are
    asserted bit-identical to the unreordered run before timing, warm
    runs are pinned to one device→host sync, and each row records the
    execution graph's ``locality_score`` (mean |u - v| across adjacency
    entries — the quantity the strategies shrink) plus the cold one-time
    permutation cost.  Results merge into ``BENCH_census.json`` under
    ``"reorder"``.
    """
    from repro.core import generators, locality_score, permute_graph
    from repro.engine import EngineConfig, clear_plan_cache, compile

    if smoke:
        g0 = generators.rmat(10, edge_factor=8, seed=0)
        chunk, reps = 512, 3
    else:
        g0 = generators.rmat(13, edge_factor=8, seed=0)
        chunk, reps = 2048, 4
    rng = np.random.default_rng(0)
    g = permute_graph(g0, rng.permutation(g0.n).astype(np.int64))
    clear_plan_cache()
    strategies = ("none", "degree", "bfs", "rcm")
    plans, cold_s, locality = [], [], []
    baseline = None
    for strat in strategies:
        cfg = EngineConfig(backend="xla", batch=256, chunk_dyads=chunk,
                           reorder=strat)
        plan = compile(g, ("triad_census",), cfg)
        t0 = time.perf_counter()
        ref = plan.run(g)["triad_census"].counts  # cold: permute + trace
        cold_s.append(time.perf_counter() - t0)
        baseline = ref if baseline is None else baseline
        assert (ref == baseline).all()  # bit-identity before any timing
        g_exec, _ = plan._reordered(g)
        locality.append(locality_score(g_exec))
        plans.append(plan)
    # interleave warm reps across strategies so machine drift hits them
    # equally; min-of-reps.
    warms = [float("inf")] * len(plans)
    s0s = [p.stats["host_syncs"] for p in plans]
    r0s = [p.stats["runs"] for p in plans]
    for _ in range(reps):
        for i, plan in enumerate(plans):
            t0 = time.perf_counter()
            plan.run(g)
            warms[i] = min(warms[i], time.perf_counter() - t0)
    rows = []
    for strat, plan, warm, cold, loc, s0, r0 in zip(
            strategies, plans, warms, cold_s, locality, s0s, r0s):
        syncs = ((plan.stats["host_syncs"] - s0)
                 / max(plan.stats["runs"] - r0, 1))
        assert syncs == 1.0, (strat, syncs)  # warm reorder keeps one sync
        assert plan.stats["reorders"] <= 1   # memoized: one cold permute
        row = dict(reorder=strat, warm_s=warm,
                   dyads_per_sec=g.n_dyads / max(warm, 1e-9),
                   cold_s=cold, locality_score=loc,
                   host_syncs_per_run=syncs)
        rows.append(row)
        print(f"census_reorder_{strat},{warm * 1e6:.0f},"
              f"dyads_per_sec={row['dyads_per_sec']:.0f}"
              f",locality={loc:.1f}")
    best = min(rows[1:], key=lambda r: r["warm_s"])
    speedup = rows[0]["warm_s"] / max(best["warm_s"], 1e-9)
    print(f"census_reorder_best,0,{best['reorder']}_vs_none={speedup:.2f}x")
    _merge_json(out, schema=1, jax_backend=jax.default_backend(),
                reorder=dict(smoke=smoke,
                             graph=dict(n=g.n, m=g.m, dyads=g.n_dyads),
                             results=rows, best=best["reorder"],
                             best_speedup=speedup))
    print(f"# wrote {out}")


def bench_partition(scale: float, *, smoke: bool = False,
                    out: str = "BENCH_census.json"):
    """``--partition``: concurrent vs serial partitioned execution over
    8 virtual devices, plus the per-device memory drop.

    Runs the census on a degree-skewed R-MAT graph unpartitioned
    (``p1``), ``partitions=8`` forced serial (``p8-serial``: shards
    staged once but folded one at a time on the primary device),
    ``partitions=8`` in the default pool mode (``p8-pool``: every shard
    resident on its own device, driven concurrently through the shared
    workqueue with device-side halo exchange), and ``partitions=8``
    with spill scratch (``p8-spill``, resolved to serial).  Bit-identity
    with the unpartitioned raw result and the ONE device→host sync per
    run are asserted **before** any timing.  The concurrency gate is
    asserted before timings are recorded: pool-mode ``shard_overlap``
    must show genuinely overlapped shard execution and halo rows must
    move device-to-device (``d2d_puts > 0``); on hosts with >= 2
    physical cores pool wall-clock must beat serial, on a single core
    (where 8 virtual devices share one CPU) pool must stay within a
    bounded coordination overhead of serial.  A second banded-locality
    graph measures ``stats["partition"]["max_shard_bytes"]`` against
    the unpartitioned context footprint and asserts the per-device
    bytes drop at P=8 is at least 2x.  Like ``--executor``, this
    re-execs itself once under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` when only
    one CPU device is visible.  Results merge into
    ``BENCH_census.json`` under ``"partition"``: per-case warm times
    with mode / h2d_puts / d2d_puts / shard_overlap, the pool-vs-serial
    speedup, and the memory section.
    """
    import os
    import tempfile

    n_dev = len(jax.devices())
    if (n_dev < 2 and jax.default_backend() == "cpu"
            and not os.environ.get("_REPRO_PARTITION_REEXEC")):
        import subprocess
        import sys
        env = {**os.environ, "_REPRO_PARTITION_REEXEC": "1"}
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=8"
                            ).strip()
        cmd = [sys.executable, __file__, "--partition", "--scale",
               str(scale), "--out", out] + (["--smoke"] if smoke else [])
        r = subprocess.run(cmd, env=env)
        if r.returncode:
            raise RuntimeError(
                f"partition bench subprocess failed ({r.returncode})")
        return  # child merged its 'partition' section into the JSON

    from repro.core import generators
    from repro.engine import EngineConfig, clear_plan_cache, compile
    from repro.engine.partition import full_context_bytes

    if smoke:
        g = generators.rmat(10, edge_factor=8, seed=0)
        chunk, reps = 512, 3
        mem_n, mem_k = 4096, 4
    else:
        g = generators.rmat(13, edge_factor=8, seed=0)
        chunk, reps = 2048, 4
        mem_n, mem_k = 16384, 6
    clear_plan_cache()
    scratch = tempfile.mkdtemp(prefix="bench-spill-")
    cases = [("p1", dict()),
             ("p8-serial", dict(partitions=8, schedule="dynamic",
                                partition_mode="serial")),
             ("p8-pool", dict(partitions=8, schedule="dynamic")),
             ("p8-spill", dict(partitions=8, schedule="dynamic",
                               spill=scratch))]
    plans, baseline = [], None
    for name, kw in cases:
        cfg = EngineConfig(backend="xla", batch=256, chunk_dyads=chunk,
                           **kw)
        plan = compile(g, ("triad_census",), cfg)
        s0 = plan.stats["host_syncs"]
        raw = plan.run_raw(g)  # warm + correctness gate before timing
        assert plan.stats["host_syncs"] - s0 == 1, name  # ONE sync
        baseline = raw if baseline is None else baseline
        assert np.array_equal(raw, baseline), name  # bit-identity
        plans.append(plan)
    serial_i, pool_i, spill_i = 1, 2, 3
    assert plans[pool_i].partition_mode == "pool", \
        plans[pool_i].partition_mode  # 8 devices visible -> concurrent
    # Concurrency gate, asserted before any timing is recorded: the
    # pool pass must genuinely interleave shard execution across the
    # device pool and move halo rows device-to-device.
    ps_pool = plans[pool_i].stats["partition"]
    assert ps_pool["shard_overlap"] >= 0.5, ps_pool["shard_overlap"]
    assert ps_pool["d2d_puts"] > 0
    pool_devs = {t["device"] for t in ps_pool["shard_times"].values()}
    assert len(pool_devs) > 1, pool_devs
    warms = [float("inf")] * len(plans)
    for _ in range(reps):
        for i, plan in enumerate(plans):
            t0 = time.perf_counter()
            plan.run_raw(g)
            warms[i] = min(warms[i], time.perf_counter() - t0)
    # Throughput gate: with real parallel hardware the concurrent pool
    # must beat the serial fold; 8 virtual devices pinned to a single
    # physical core cannot speed up compute-bound shards, so there we
    # only bound the thread-coordination overhead.
    if (os.cpu_count() or 1) >= 2:
        assert warms[pool_i] <= warms[serial_i], \
            (warms[pool_i], warms[serial_i])
    else:
        assert warms[pool_i] <= 1.6 * warms[serial_i], \
            (warms[pool_i], warms[serial_i])
    rows = []
    for (name, _), plan, warm in zip(cases, plans, warms):
        row = dict(case=name, partitions=plan.partitions, warm_s=warm,
                   dyads_per_sec=g.n_dyads / max(warm, 1e-9))
        ps = plan.stats.get("partition")
        if ps:
            row.update(mode=ps["mode"],
                       shard_dyads=list(ps["shard_dyads"]),
                       halo_sizes=list(ps["halo_sizes"]),
                       spill=bool(ps["spill"]),
                       h2d_puts=int(ps["h2d_puts"]),
                       d2d_puts=int(ps["d2d_puts"]),
                       shard_overlap=float(ps["shard_overlap"]),
                       max_shard_bytes=int(ps["max_shard_bytes"]),
                       max_stage_bytes=int(ps["max_stage_bytes"]),
                       stream_bytes=int(ps["stream_bytes"]))
        rows.append(row)
        print(f"census_partition_{name},{warm * 1e6:.0f},"
              f"dyads_per_sec={row['dyads_per_sec']:.0f}")
    overhead = warms[pool_i] / max(warms[0], 1e-9)
    pool_speedup = warms[serial_i] / max(warms[pool_i], 1e-9)
    spill_tax = warms[spill_i] / max(warms[serial_i], 1e-9)
    print(f"census_partition_overhead,0,p8_vs_p1={overhead:.2f}x"
          f",spill_tax={spill_tax:.2f}x")
    print(f"census_partition_concurrency,0,"
          f"pool_vs_serial={pool_speedup:.2f}x,"
          f"overlap={ps_pool['shard_overlap']:.2f},"
          f"cores={os.cpu_count()}")
    # Memory section: on a locality-rich banded graph the resident
    # per-device context at P=8 must be a small fraction of the
    # unpartitioned footprint (R-MAT hubs land in every halo and cap
    # the ratio near 1.4x, so the ~P-fold claim is pinned here).
    rng = np.random.default_rng(0)
    src = np.repeat(np.arange(mem_n, dtype=np.int64), mem_k)
    dst = (src + rng.integers(1, 64, size=src.size)) % mem_n
    gm = generators.from_edges(mem_n, src, dst)
    mem_p1 = compile(gm, ("triad_census",),
                     EngineConfig(backend="xla", batch=256,
                                  chunk_dyads=chunk))
    mem_p8 = compile(gm, ("triad_census",),
                     EngineConfig(backend="xla", batch=256,
                                  chunk_dyads=chunk, partitions=8,
                                  schedule="dynamic"))
    assert np.array_equal(mem_p8.run_raw(gm), mem_p1.run_raw(gm))
    full_bytes = full_context_bytes(mem_p8)
    shard_bytes = int(mem_p8.stats["partition"]["max_shard_bytes"])
    mem_ratio = full_bytes / max(shard_bytes, 1)
    assert mem_ratio >= 2.0, mem_ratio  # per-device bytes drop at P=8
    print(f"census_partition_memory,0,full_bytes={full_bytes},"
          f"max_shard_bytes={shard_bytes},ratio={mem_ratio:.2f}x")
    _merge_json(out, schema=1, jax_backend=jax.default_backend(),
                partition=dict(smoke=smoke, n_devices_visible=n_dev,
                               graph=dict(n=g.n, m=g.m, dyads=g.n_dyads),
                               results=rows, p8_overhead=overhead,
                               pool_vs_serial=pool_speedup,
                               spill_tax=spill_tax,
                               memory=dict(graph=dict(n=gm.n, m=gm.m),
                                           full_bytes=int(full_bytes),
                                           max_shard_bytes=shard_bytes,
                                           ratio=mem_ratio)))
    import shutil
    shutil.rmtree(scratch, ignore_errors=True)
    print(f"# wrote {out}")


def bench_lm_smoke(scale: float):
    """Framework-side: smoke-scale train-step latency per arch."""
    from repro.config import RunConfig, get_config, list_configs
    from repro.models import transformer as tfm
    from repro.train import adamw_init, make_train_step

    run = RunConfig(attention_impl="chunked_causal", attention_chunk=16,
                    remat="none")
    for arch in list_configs():
        cfg = get_config(arch, smoke=True)
        params = tfm.init_model(cfg, jax.random.PRNGKey(0))
        opt = adamw_init(params)
        step = jax.jit(make_train_step(cfg, run))
        batch = {"tokens": jnp.zeros((2, 33), jnp.int32)}
        if cfg.n_prefix_embeds:
            batch["prefix_embeds"] = jnp.zeros(
                (2, cfg.n_prefix_embeds, cfg.d_model), jnp.bfloat16)
        t = _timeit(lambda: step(params, opt, batch)[2]["loss"])
        print(f"lm_train_step_smoke_{arch},{t:.0f},B2xT32")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0,
                    help="graph size multiplier (1.0 = CPU-sized)")
    ap.add_argument("--only", default="")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI pass: device-pipeline bench on tiny "
                         "graphs, writes BENCH_census.json")
    ap.add_argument("--serve", action="store_true",
                    help="fleet serving bench: batched CensusService vs "
                         "sequential plan.run requests/sec (merges a "
                         "'serve' section into the JSON)")
    ap.add_argument("--ops", action="store_true",
                    help="GraphOp bench: per-op passes vs one fused "
                         "multi-analytic pass (merges an 'ops' section "
                         "into the JSON)")
    ap.add_argument("--executor", action="store_true",
                    help="executor bench: static vs dynamic schedule, "
                         "1 vs N virtual devices (merges an 'executor' "
                         "section into the JSON; re-execs itself under "
                         "forced 8 host devices when needed)")
    ap.add_argument("--delta", action="store_true",
                    help="delta bench: incremental apply_delta vs full "
                         "recompute across mutation footprints, plus "
                         "subscribed-session vs resubmission rates "
                         "(merges a 'delta' section into the JSON)")
    ap.add_argument("--faults", action="store_true",
                    help="robustness bench: inert vs armed vs recovering "
                         "fault plans — the fault-free overhead and the "
                         "recovery tax (merges a 'faults' section into "
                         "the JSON)")
    ap.add_argument("--reorder", action="store_true",
                    help="locality bench: warm census throughput per "
                         "reorder strategy (none/degree/bfs/rcm) on a "
                         "label-scrambled degree-skewed graph (merges a "
                         "'reorder' section into the JSON)")
    ap.add_argument("--partition", action="store_true",
                    help="partition bench: sharded-CSR runs, 1 vs 8 "
                         "shards over 8 virtual devices, spill off/on, "
                         "bit-identity + one-sync asserted before timing "
                         "(merges a 'partition' section into the JSON; "
                         "re-execs itself under forced 8 host devices "
                         "when needed)")
    ap.add_argument("--sync-baseline", action="store_true",
                    help="also time the synchronous (device_accum=False) "
                         "data path for an A/B speedup in the JSON")
    ap.add_argument("--out", default="BENCH_census.json",
                    help="device-pipeline JSON output path")
    args = ap.parse_args()

    def device_pipeline(scale):
        bench_device_pipeline(scale, sync_baseline=args.sync_baseline,
                              smoke=args.smoke, out=args.out)

    print("name,us_per_call,derived")
    if args.serve:
        bench_serve(args.scale, smoke=args.smoke, out=args.out)
        return
    if args.ops:
        bench_ops(args.scale, smoke=args.smoke, out=args.out)
        return
    if args.executor:
        bench_executor(args.scale, smoke=args.smoke, out=args.out)
        return
    if args.delta:
        bench_delta(args.scale, smoke=args.smoke, out=args.out)
        return
    if args.faults:
        bench_faults(args.scale, smoke=args.smoke, out=args.out)
        return
    if args.reorder:
        bench_reorder(args.scale, smoke=args.smoke, out=args.out)
        return
    if args.partition:
        bench_partition(args.scale, smoke=args.smoke, out=args.out)
        return
    if args.smoke:
        device_pipeline(args.scale)
        return
    benches = {
        "census_versions": bench_census_versions,
        "balance": bench_balance,
        "accumulators": bench_accumulators,
        "scaling": bench_scaling,
        "kernel": bench_kernel,
        "engine_cache": bench_engine_cache,
        "device_pipeline": device_pipeline,
        "serve": lambda s: bench_serve(s, smoke=False, out=args.out),
        "ops": lambda s: bench_ops(s, smoke=False, out=args.out),
        "executor": lambda s: bench_executor(s, smoke=False, out=args.out),
        "delta": lambda s: bench_delta(s, smoke=False, out=args.out),
        "faults": lambda s: bench_faults(s, smoke=False, out=args.out),
        "partition": lambda s: bench_partition(s, smoke=False, out=args.out),
        "lm_smoke": bench_lm_smoke,
    }
    only = [s for s in args.only.split(",") if s]
    for name, fn in benches.items():
        if only and name not in only:
            continue
        fn(args.scale)


if __name__ == "__main__":
    main()
