"""Shared neural layers: norms, RoPE, SwiGLU MLP, embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .params import ParamDef


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def rope_tables(positions: jax.Array, dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for given positions: (..., dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, T, H, D); cos/sin: (B, T, D//2) — llama rotate-half convention."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


def mlp_defs(d_model: int, d_ff: int) -> dict[str, ParamDef]:
    return {
        "w_gate": ParamDef((d_model, d_ff), ("embed", "ff")),
        "w_up": ParamDef((d_model, d_ff), ("embed", "ff")),
        "w_down": ParamDef((d_ff, d_model), ("ff", "embed")),
    }


def mlp_apply(p: dict, prefix: str, x: jax.Array, dtype) -> jax.Array:
    g = x @ p[prefix + "w_gate"].astype(dtype)
    u = x @ p[prefix + "w_up"].astype(dtype)
    return (jax.nn.silu(g) * u) @ p[prefix + "w_down"].astype(dtype)
