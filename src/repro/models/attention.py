"""Attention: GQA (dense / chunked flash-style XLA / sliding window) and MLA.

Weight layout: projection outputs are stored *flattened* ``(d, H*hd)`` so
the 16-way model axis always divides the sharded dim even when the head
count (20/24/56) does not; per-head tensors exist only as jit-internal
values where GSPMD's padded propagation is allowed.

``chunked_causal`` is the production prefill path: a python-unrolled loop
over query chunks where chunk ``i`` attends only kv chunks ``0..i`` (a
*triangular* schedule — no FLOPs are spent on fully-masked blocks, unlike
the rectangular masked variant kept as the paper-faithful/naive baseline),
with an online-softmax scan over kv chunks inside (flash attention
expressed in XLA; the Pallas kernel in repro.kernels is the TPU-native
twin and is numerically checked against this).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..config.base import ModelConfig, RunConfig
from .layers import apply_rope, rms_norm, rope_tables
from .params import ParamDef

NEG_INF = -1e30


def _einsum_f32(subscripts, a, b):
    """einsum with f32 accumulation: native mixed dot on TPU, explicit
    casts on CPU (XLA:CPU's DotThunk cannot execute bf16xbf16->f32)."""
    if jax.default_backend() == "tpu":
        return jnp.einsum(subscripts, a, b,
                          preferred_element_type=jnp.float32)
    return jnp.einsum(subscripts, a.astype(jnp.float32),
                      b.astype(jnp.float32))


class AttnCache(NamedTuple):
    """Decode cache with flattened kv feature dim (B, S, Hkv*hd).

    ``pos`` stores the absolute position held in each slot (sentinel 2**30
    = empty), which makes sliding-window caches plain ring buffers: the
    write index is ``position % S`` and masking falls out of the standard
    position comparison.
    """

    k: jax.Array
    v: jax.Array
    pos: jax.Array  # (B, S) int32


def attn_defs(cfg: ModelConfig) -> dict[str, ParamDef]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    out_q, out_kv = cfg.n_heads * hd, cfg.n_kv_heads * hd
    defs = {
        "wq": ParamDef((d, out_q), ("embed", "heads_flat")),
        "wk": ParamDef((d, out_kv), ("embed", "kv_flat")),
        "wv": ParamDef((d, out_kv), ("embed", "kv_flat")),
        "wo": ParamDef((out_q, d), ("heads_flat", "embed")),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((out_q,), ("heads_flat",), "zeros")
        defs["bk"] = ParamDef((out_kv,), ("kv_flat",), "zeros")
        defs["bv"] = ParamDef((out_kv,), ("kv_flat",), "zeros")
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((hd,), (None,), "ones")
        defs["k_norm"] = ParamDef((hd,), (None,), "ones")
    return defs


def _grouped(q, k):
    """reshape q to (B, T, Hkv, G, hd) matching k's (B, S, Hkv, hd)."""
    B, T, H, hd = q.shape
    Hkv = k.shape[2]
    return q.reshape(B, T, Hkv, H // Hkv, hd)


def _dense_attention(q, k, v, q_pos, kv_pos, window: Optional[int]):
    """Reference rectangular attention (paper-faithful naive baseline)."""
    qg = _grouped(q, k)
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("btkgd,bskd->bkgts", qg, k,
                        preferred_element_type=jnp.float32) * scale
    mask = kv_pos[:, None, :] <= q_pos[:, :, None]  # (B, T, S)
    if window is not None:
        mask &= kv_pos[:, None, :] > q_pos[:, :, None] - window
    bias = jnp.where(mask, 0.0, NEG_INF).astype(jnp.float32)  # (B, T, S)
    scores = scores + bias[:, None, None]
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", w.astype(v.dtype), v)
    return out.reshape(q.shape)


def _flash_rows(qg, k, v, q_pos, kv_pos, window, chunk):
    """Online-softmax scan over kv chunks for one query block.

    qg: (B, Tq, Hkv, G, hd);  k/v: (B, S, Hkv, hd) with S % chunk == 0.
    """
    B, Tq, Hkv, G, hd = qg.shape
    S = k.shape[1]
    n_kv = S // chunk
    scale = 1.0 / math.sqrt(hd)
    qf = (qg * scale).astype(qg.dtype)  # bf16 in, f32 MXU accumulation

    def step(carry, xs):
        m, l, acc = carry
        kc, vc, pc = xs  # (B, chunk, Hkv, hd), (B, chunk)
        s = jnp.einsum("btkgd,bskd->bkgts", qf, kc,
                       preferred_element_type=jnp.float32)
        mask = pc[:, None, :] <= q_pos[:, :, None]
        if window is not None:
            mask &= pc[:, None, :] > q_pos[:, :, None] - window
        bias = jnp.where(mask, 0.0, NEG_INF).astype(jnp.float32)  # (B,Tq,C)
        s = s + bias[:, None, None]
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgts,bskd->bkgtd", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    init = (
        jnp.full((B, Hkv, G, Tq), NEG_INF, jnp.float32),
        jnp.zeros((B, Hkv, G, Tq), jnp.float32),
        jnp.zeros((B, Hkv, G, Tq, hd), jnp.float32),
    )
    xs = (
        k.reshape(B, n_kv, chunk, Hkv, hd).swapaxes(0, 1),
        v.reshape(B, n_kv, chunk, Hkv, hd).swapaxes(0, 1),
        kv_pos.reshape(B, n_kv, chunk).swapaxes(0, 1),
    )
    (m, l, acc), _ = jax.lax.scan(step, init, xs)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4)  # (B, Tq, Hkv, G, hd)


def _chunked_attention(q, k, v, q_pos, kv_pos, window, chunk, *, triangular,
                       remat_rows=True):
    """Flash-style attention; ``triangular=True`` skips above-diagonal blocks.

    ``remat_rows`` recomputes each row's kv scan in the backward pass
    instead of stashing per-iteration scores (flash-attention backward,
    expressed in XLA) — trades ~one extra attention forward for an
    O(T·chunk)-per-row score stash.
    """
    B, T, H, hd = q.shape
    chunk = min(chunk, T)
    if T % chunk:
        chunk = math.gcd(T, chunk) or T
    n_q = T // chunk
    qg = _grouped(q, k)
    rows = _flash_rows
    if remat_rows:
        rows = jax.checkpoint(_flash_rows, prevent_cse=False,
                              static_argnums=(5, 6))
    outs = []
    for i in range(n_q):  # python-unrolled: static shapes per row
        sl = slice(i * chunk, (i + 1) * chunk)
        if triangular:
            lo = 0
            if window is not None:
                lo = max(0, (i * chunk - window) // chunk)
            kv_hi = (i + 1) * chunk
            ks, vs, ps = (k[:, lo * chunk:kv_hi], v[:, lo * chunk:kv_hi],
                          kv_pos[:, lo * chunk:kv_hi])
        else:
            ks, vs, ps = k, v, kv_pos
        o = rows(qg[:, sl], ks, vs, q_pos[:, sl], ps, window, chunk)
        outs.append(o)
    out = jnp.concatenate(outs, axis=1)
    return out.reshape(B, T, H, hd).astype(v.dtype)


def _decode_attention(q, k, v, q_pos, kv_pos, window):
    """Single-token decode: q (B, 1, H, hd) vs full cache (B, S, Hkv, hd)."""
    qg = _grouped(q, k)
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("btkgd,bskd->bkgts", qg, k,
                   preferred_element_type=jnp.float32) * scale
    mask = kv_pos[:, None, :] <= q_pos[:, :, None]
    if window is not None:
        mask &= kv_pos[:, None, :] > q_pos[:, :, None] - window
    s = s + jnp.where(mask, 0.0, NEG_INF).astype(jnp.float32)[:, None, None]
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", w, v)
    return out.reshape(q.shape)


def attention_core(q, k, v, q_pos, kv_pos, *, impl: str, chunk: int,
                   window: Optional[int], remat_rows: bool = True):
    if q.shape[1] == 1 and k.shape[1] > 1:
        return _decode_attention(q, k, v, q_pos, kv_pos, window)
    if impl == "dense":
        return _dense_attention(q, k, v, q_pos, kv_pos, window)
    if impl == "chunked":
        return _chunked_attention(q, k, v, q_pos, kv_pos, window, chunk,
                                  triangular=False, remat_rows=remat_rows)
    if impl in ("chunked_causal", "pallas"):
        # 'pallas' resolves to the Pallas kernel on TPU via kernels.ops;
        # inside pure-XLA lowering contexts we use the triangular XLA twin.
        if impl == "pallas":
            try:
                from ..kernels import ops as kops
                return kops.flash_attention(q, k, v, q_pos, kv_pos,
                                            window=window, chunk=chunk)
            except Exception:
                pass
        return _chunked_attention(q, k, v, q_pos, kv_pos, window, chunk,
                                  triangular=True, remat_rows=remat_rows)
    raise ValueError(f"unknown attention impl {impl!r}")


def gqa_apply(cfg: ModelConfig, run: RunConfig, p: dict, prefix: str,
              x: jax.Array, positions: jax.Array,
              cache: Optional[AttnCache] = None, cache_pos=None):
    """Full GQA block body (no residual/norm). Returns (out, new_cache)."""
    B, T, d = x.shape
    hd = cfg.resolved_head_dim
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    dtype = x.dtype

    q = x @ p[prefix + "wq"].astype(dtype)
    k = x @ p[prefix + "wk"].astype(dtype)
    v = x @ p[prefix + "wv"].astype(dtype)
    if cfg.qkv_bias:
        q = q + p[prefix + "bq"].astype(dtype)
        k = k + p[prefix + "bk"].astype(dtype)
        v = v + p[prefix + "bv"].astype(dtype)
    q = q.reshape(B, T, H, hd)
    k = k.reshape(B, T, Hkv, hd)
    v = v.reshape(B, T, Hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p[prefix + "q_norm"], cfg.norm_eps)
        k = rms_norm(k, p[prefix + "k_norm"], cfg.norm_eps)
    cos, sin = rope_tables(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    new_cache = None
    if cache is not None:
        S = cache.k.shape[1]
        kf = k.reshape(B, T, Hkv * hd)
        vf = v.reshape(B, T, Hkv * hd)
        write = cache_pos % S  # ring buffer for sliding-window caches
        ck = jax.lax.dynamic_update_slice(cache.k, kf.astype(cache.k.dtype),
                                          (0, write, 0))
        cv = jax.lax.dynamic_update_slice(cache.v, vf.astype(cache.v.dtype),
                                          (0, write, 0))
        newpos = positions.astype(jnp.int32)
        cp = jax.lax.dynamic_update_slice(cache.pos, newpos, (0, write))
        new_cache = AttnCache(k=ck, v=cv, pos=cp)
        k = ck.reshape(B, S, Hkv, hd).astype(dtype)
        v = cv.reshape(B, S, Hkv, hd).astype(dtype)
        kv_pos = cp
    else:
        kv_pos = positions

    out = attention_core(q, k, v, positions, kv_pos, impl=run.attention_impl,
                         chunk=run.attention_chunk, window=cfg.sliding_window,
                         remat_rows=getattr(run, "remat_attention", True))
    out = out.reshape(B, T, H * hd)
    return out @ p[prefix + "wo"].astype(dtype), new_cache


# ----------------------------------------------------------------------------
# MLA (deepseek-v2): compressed-KV attention with absorbed decode path.
# ----------------------------------------------------------------------------

class MLACache(NamedTuple):
    ckv: jax.Array  # (B, S, kv_lora)
    krope: jax.Array  # (B, S, rope_dim)
    pos: jax.Array  # (B, S) int32; sentinel 2**30 = empty


def mla_defs(cfg: ModelConfig) -> dict[str, ParamDef]:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk = m.nope_head_dim + m.rope_head_dim
    return {
        "wq_a": ParamDef((d, m.q_lora_rank), ("embed", "lora")),
        "q_norm": ParamDef((m.q_lora_rank,), (None,), "ones"),
        "wq_b": ParamDef((m.q_lora_rank, H * qk), ("lora", "heads_flat")),
        "wkv_a": ParamDef((d, m.kv_lora_rank + m.rope_head_dim), ("embed", "lora")),
        "kv_norm": ParamDef((m.kv_lora_rank,), (None,), "ones"),
        "wk_b": ParamDef((m.kv_lora_rank, H * m.nope_head_dim), ("lora", "heads_flat")),
        "wv_b": ParamDef((m.kv_lora_rank, H * m.v_head_dim), ("lora", "heads_flat")),
        "wo": ParamDef((H * m.v_head_dim, d), ("heads_flat", "embed")),
    }


def mla_apply(cfg: ModelConfig, run: RunConfig, p: dict, prefix: str,
              x: jax.Array, positions: jax.Array,
              cache: Optional[MLACache] = None, cache_pos=None):
    m = cfg.mla
    B, T, d = x.shape
    H = cfg.n_heads
    dtype = x.dtype

    q = rms_norm(x @ p[prefix + "wq_a"].astype(dtype), p[prefix + "q_norm"],
                 cfg.norm_eps)
    q = (q @ p[prefix + "wq_b"].astype(dtype)).reshape(
        B, T, H, m.nope_head_dim + m.rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)
    cos, sin = rope_tables(positions, m.rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    kv = x @ p[prefix + "wkv_a"].astype(dtype)
    ckv, krope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    ckv = rms_norm(ckv, p[prefix + "kv_norm"], cfg.norm_eps)
    krope = apply_rope(krope[:, :, None, :], cos, sin)[:, :, 0, :]

    new_cache = None
    if cache is not None:
        cc = jax.lax.dynamic_update_slice(cache.ckv, ckv.astype(cache.ckv.dtype),
                                          (0, cache_pos, 0))
        cr = jax.lax.dynamic_update_slice(cache.krope,
                                          krope.astype(cache.krope.dtype),
                                          (0, cache_pos, 0))
        cp = jax.lax.dynamic_update_slice(cache.pos, positions.astype(jnp.int32),
                                          (0, cache_pos))
        new_cache = MLACache(ckv=cc, krope=cr, pos=cp)
        ckv_full, krope_full = cc.astype(dtype), cr.astype(dtype)
        kv_pos = cp
    else:
        ckv_full, krope_full = ckv, krope
        kv_pos = positions

    scale = 1.0 / math.sqrt(m.nope_head_dim + m.rope_head_dim)
    wk_b = p[prefix + "wk_b"].astype(dtype).reshape(m.kv_lora_rank, H,
                                                    m.nope_head_dim)
    wv_b = p[prefix + "wv_b"].astype(dtype).reshape(m.kv_lora_rank, H,
                                                    m.v_head_dim)
    if T == 1 and ckv_full.shape[1] > 1:
        # absorbed decode: never decompress the per-head K/V.
        q_abs = jnp.einsum("bthn,lhn->bthl", q_nope, wk_b)
        s = _einsum_f32("bthl,bsl->bhts", q_abs, ckv_full)
        s += _einsum_f32("bthr,bsr->bhts", q_rope, krope_full)
        s *= scale
        mask = kv_pos[:, None, :] <= positions[:, :, None]
        s = s + jnp.where(mask, 0.0, NEG_INF).astype(jnp.float32)[:, None]
        w = jax.nn.softmax(s, axis=-1)
        ctx = _einsum_f32("bhts,bsl->bthl", w.astype(dtype), ckv_full)
        out = _einsum_f32("bthl,lhv->bthv", ctx.astype(dtype), wv_b)
        out = out.astype(dtype)
    else:
        S = ckv_full.shape[1]
        k_nope = jnp.einsum("bsl,lhn->bshn", ckv_full, wk_b)
        v = jnp.einsum("bsl,lhv->bshv", ckv_full, wv_b)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(krope_full[:, :, None, :],
                                      (B, S, H, m.rope_head_dim))], axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        # pad v head dim up to qk head dim so the shared core can run; slice after.
        pad = qq.shape[-1] - v.shape[-1]
        v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))
        out = attention_core(qq, k, v_p, positions, kv_pos,
                             impl=run.attention_impl, chunk=run.attention_chunk,
                             window=None,
                             remat_rows=getattr(run, "remat_attention", True)
                             )[..., : m.v_head_dim]
    out = out.reshape(B, T, H * m.v_head_dim)
    return out @ p[prefix + "wo"].astype(dtype), new_cache
