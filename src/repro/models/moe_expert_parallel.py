"""True expert-parallel MoE via ``shard_map`` + explicit ``all_to_all``.

The §Perf log (EXPERIMENTS.md, cell 2) ends with grouped dispatch still
collective-bound because GSPMD realizes the buffer reshard from
batch-layout to expert-layout as all-gather + all-reduce.  This module is
the documented next iteration, written manually inside ``shard_map``:

  1. each model shard takes its 1/ep slice of the local tokens (so routing,
     sort and scatter are non-redundant across the TP axis),
  2. one ``all_to_all`` moves capacity slots from token-layout to
     expert-layout,
  3. local expert FFNs (experts are sharded over 'model'),
  4. the inverse ``all_to_all`` + an ``all_gather`` of the combined output
     restore the replicated activation layout.

Cross-device traffic = 2 x a2a(buffer/ep) + 1 x all_gather(y) — no
all-reduce, no replicated capacity buffer.  Kept separate from
``moe_apply`` (the jit/GSPMD path used by the dry-run records) so the
recorded baselines stay reproducible.

Layout contract (matches sharding.rules 'expert' mode):
  * x:        (B, T, d)  sharded P(batch_axes, None, None)
  * router:   (d, E)     replicated
  * w_gate/up:(E, d, f)  sharded P('model', None, None)
  * w_down:   (E, f, d)  sharded P('model', None, None)
Requires n_experts % model_axis == 0 and (B_loc*T) % model_axis == 0.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import compat
from ..config.base import ModelConfig
from ..sharding.rules import batch_axes
from .moe import _positions_in_expert


def make_expert_parallel_moe(cfg: ModelConfig, mesh):
    mo = cfg.moe
    ep = mesh.shape["model"]
    assert mo.n_experts % ep == 0, (mo.n_experts, ep)
    e_loc = mo.n_experts // ep
    b_axes = batch_axes(mesh)

    def local_moe(x, router, wg, wu, wd):
        # x: (B_loc, T, d) — replicated over 'model'; take this shard's slice
        Bl, T, d = x.shape
        n_all = Bl * T
        assert n_all % ep == 0, (n_all, ep)
        n = n_all // ep
        me = jax.lax.axis_index("model")
        xf = jax.lax.dynamic_slice_in_dim(x.reshape(n_all, d), me * n, n, 0)

        logits = (xf @ router.astype(x.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, -1)
        gate, ids = jax.lax.top_k(probs, mo.top_k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
        cap = max(1, int(math.ceil(n * mo.top_k / mo.n_experts
                                   * mo.capacity_factor)))
        pos = _positions_in_expert(ids.reshape(-1),
                                   mo.n_experts).reshape(n, mo.top_k)
        keep = pos < cap

        buf = jnp.zeros((mo.n_experts, cap, d), x.dtype)
        for s in range(mo.top_k):
            src = jnp.where(keep[:, s, None], xf, 0)
            buf = buf.at[ids[:, s], jnp.where(keep[:, s], pos[:, s], cap)
                         ].add(src, mode="drop")

        # dispatch a2a over 'model': token-shards -> expert-shards
        buf = buf.reshape(ep, e_loc, cap, d)
        buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=0)
        # now (ep, e_loc, cap, d): [src_shard, local_expert, slot, d]
        buf = buf.transpose(1, 0, 2, 3).reshape(e_loc, ep * cap, d)

        g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(x.dtype))
        u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(x.dtype))
        out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                         wd.astype(x.dtype))

        # combine a2a: inverse exchange back to token-shards
        out = out.reshape(e_loc, ep, cap, d).transpose(1, 0, 2, 3)
        out = jax.lax.all_to_all(out, "model", split_axis=0, concat_axis=0)
        out = out.reshape(mo.n_experts, cap, d)

        y = jnp.zeros((n, d), x.dtype)
        for s in range(mo.top_k):
            contrib = out[ids[:, s], jnp.minimum(pos[:, s], cap - 1)]
            w = jnp.where(keep[:, s], gate[:, s], 0).astype(x.dtype)
            y = y + contrib * w[:, None]
        # restore the replicated-over-'model' activation layout
        y_all = jax.lax.all_gather(y, "model", axis=0, tiled=True)
        return y_all.reshape(Bl, T, d)

    shmap = compat.shard_map(
        local_moe,
        mesh=mesh,
        in_specs=(P(b_axes, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=P(b_axes, None, None),
        check_vma=False,
    )

    def apply(p: dict, prefix: str, x: jax.Array):
        return shmap(x, p[prefix + "router"], p[prefix + "w_gate"],
                     p[prefix + "w_up"], p[prefix + "w_down"])

    return apply
