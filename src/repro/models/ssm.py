"""Mamba2 (SSD) block — chunked, MXU-friendly formulation.

The selective-state-space recurrence

    h_t = exp(dt_t * a) * h_{t-1} + dt_t * B_t x_t,     y_t = C_t . h_t + D x_t

is evaluated with the SSD chunk decomposition (Dao & Gu 2024): the sequence
is split into chunks of length L; within a chunk the contribution is a
masked (L, L) matmul (quadratic-but-tiny, lands on the MXU), across chunks a
``lax.scan`` carries the (H, P, N) state.  This is the TPU-native analogue
of the paper's "turn irregular recurrence into dense blocked compute".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..config.base import ModelConfig
from .layers import rms_norm
from .params import ParamDef


def ssm_defs(cfg: ModelConfig) -> dict[str, ParamDef]:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    gn = s.n_groups * s.d_state
    H = di // s.head_dim
    return {
        "wx": ParamDef((d, di), ("embed", "ff")),
        "wz": ParamDef((d, di), ("embed", "ff")),
        "wB": ParamDef((d, gn), ("embed", None)),
        "wC": ParamDef((d, gn), ("embed", None)),
        "wdt": ParamDef((d, H), ("embed", None)),
        "conv_x": ParamDef((s.conv_width, di), (None, "ff"), "normal", 0.5),
        "conv_B": ParamDef((s.conv_width, gn), (None, None), "normal", 0.5),
        "conv_C": ParamDef((s.conv_width, gn), (None, None), "normal", 0.5),
        "A_log": ParamDef((H,), (None,), "zeros"),
        "D": ParamDef((H,), (None,), "ones"),
        "dt_bias": ParamDef((H,), (None,), "zeros"),
        "norm": ParamDef((di,), (None,), "ones"),
        "wo": ParamDef((di, d), ("ff", "embed")),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x: (B, T, C), w: (W, C); state: (B, W-1, C)."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(W))
    new_state = xp[:, -(W - 1):] if W > 1 else None
    return jax.nn.silu(out), new_state


def _segsum_exp(l):
    """exp(cumsum segment sums): (..., L) -> (..., L, L) lower-tri decay."""
    L = l.shape[-1]
    cs = jnp.cumsum(l, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # (.., t, s) = sum_{s+1..t}
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_chunked(x, dt, a, B_mat, C_mat, chunk, state0=None):
    """SSD scan.  x: (B,T,H,P), dt: (B,T,H), a: (H,), B/C: (B,T,N).

    Returns (y, final_state) with state (B,H,P,N). float32 internally.
    """
    Bsz, T, H, P = x.shape
    N = B_mat.shape[-1]
    L = min(chunk, T)
    nc = T // L
    xf = x.astype(jnp.float32).reshape(Bsz, nc, L, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bsz, nc, L, H)
    Bf = B_mat.astype(jnp.float32).reshape(Bsz, nc, L, N)
    Cf = C_mat.astype(jnp.float32).reshape(Bsz, nc, L, N)
    l = dtf * a  # (B,nc,L,H) negative decay logs
    if state0 is None:
        state0 = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def step(S, xs):
        xc, dtc, Bc, Cc, lc = xs  # (B,L,H,P), (B,L,H), (B,L,N), (B,L,N), (B,L,H)
        cs = jnp.cumsum(lc, axis=1)  # (B,L,H)
        # inter-chunk: y_t += C_t . (exp(cs_t) * S_prev)
        y_inter = jnp.einsum("bln,blh,bhpn->blhp", Cc, jnp.exp(cs), S)
        # intra-chunk: masked (L,L) decay matmul
        Dm = _segsum_exp(jnp.moveaxis(lc, -1, 1))  # (B,H,L,L)
        CB = jnp.einsum("bln,bsn->bls", Cc, Bc)
        y_intra = jnp.einsum("bls,bhls,bsh,bshp->blhp", CB, Dm, dtc, xc)
        # state update
        decay_tail = jnp.exp(cs[:, -1:] - cs)  # (B,L,H): prod_{s+1..L}
        S_chunk = jnp.einsum("bsn,bsh,bshp->bhpn", Bc, decay_tail * dtc, xc)
        S_new = jnp.exp(cs[:, -1])[..., None, None] * S + S_chunk
        return S_new, y_inter + y_intra

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (xf, dtf, Bf, Cf, l))
    S_fin, ys = jax.lax.scan(step, state0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, T, H, P)
    return y, S_fin


def ssm_apply(cfg: ModelConfig, p: dict, prefix: str, x: jax.Array,
              cache: dict | None = None):
    """Mamba2 block body. cache: {'conv_x','conv_B','conv_C','state'} or None."""
    s = cfg.ssm
    B, T, d = x.shape
    di = s.expand * d
    H = di // s.head_dim
    N = s.n_groups * s.d_state
    dtype = x.dtype

    z = x @ p[prefix + "wz"].astype(dtype)
    xi = x @ p[prefix + "wx"].astype(dtype)
    Bm = x @ p[prefix + "wB"].astype(dtype)
    Cm = x @ p[prefix + "wC"].astype(dtype)
    dt = jax.nn.softplus((x @ p[prefix + "wdt"].astype(dtype)).astype(jnp.float32)
                         + p[prefix + "dt_bias"].astype(jnp.float32))

    cx = cache.get("conv_x") if cache else None
    cB = cache.get("conv_B") if cache else None
    cC = cache.get("conv_C") if cache else None
    xi, ncx = _causal_conv(xi, p[prefix + "conv_x"].astype(dtype), cx)
    Bm, ncB = _causal_conv(Bm, p[prefix + "conv_B"].astype(dtype), cB)
    Cm, ncC = _causal_conv(Cm, p[prefix + "conv_C"].astype(dtype), cC)

    a = -jnp.exp(p[prefix + "A_log"].astype(jnp.float32))  # (H,)
    xh = xi.reshape(B, T, H, s.head_dim)
    state0 = cache.get("state") if cache else None

    if T == 1 and cache is not None:
        # exact single-step decode
        da = jnp.exp(dt[:, 0] * a)  # (B,H)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0],
                         Bm[:, 0].astype(jnp.float32),
                         xh[:, 0].astype(jnp.float32))
        S = state0 * da[..., None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), S)
        y = y[:, None]  # (B,1,H,P)
        new_state = S
    else:
        y, new_state = ssd_chunked(xh, dt, a, Bm, Cm, s.chunk, state0)

    y = y + p[prefix + "D"].astype(jnp.float32)[:, None] * xh.astype(jnp.float32)
    y = y.reshape(B, T, di).astype(dtype)
    y = rms_norm(y * jax.nn.silu(z), p[prefix + "norm"], cfg.norm_eps)
    out = y @ p[prefix + "wo"].astype(dtype)
    new_cache = None
    if cache is not None:
        new_cache = {"conv_x": ncx, "conv_B": ncB, "conv_C": ncC,
                     "state": new_state}
    return out, new_cache
