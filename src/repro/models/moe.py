"""Mixture-of-Experts with static-capacity balanced dispatch.

This is where the paper's discipline transfers to the LM side (DESIGN.md
§4): irregular work (tokens routed to experts ~ dyad tasks routed to
workers) is packed into **static, balanced shards** (per-expert capacity
slots ~ per-thread task queues), computed independently, and merged once at
the end (combine-by-gather ~ the decoupled census merge).  Routing
positions are computed with a sort over (token, expert) pairs — the same
sorted-packing idea as ``core.balance.sorted_snake`` — instead of the
O(tokens x experts) cumsum one-hot, which would not fit at 1M tokens.

Sharding modes (see sharding.rules.make_rules):
  * ``expert``: experts on the model axis (deepseek-v2: 160 % 16 == 0).
  * ``tensor``: experts replicated, each expert's ffn tensor-parallel
    (granite-moe: 40 experts do not divide the 16-way axis).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..config.base import ModelConfig
from .layers import mlp_defs, mlp_apply
from .params import ParamDef, prefixed


def moe_defs(cfg: ModelConfig) -> dict[str, ParamDef]:
    mo = cfg.moe
    d = cfg.d_model
    defs = {
        "router": ParamDef((d, mo.n_experts), ("embed", None)),
        "w_gate": ParamDef((mo.n_experts, d, mo.d_ff_expert),
                           ("experts", "expert_embed", "expert_ff")),
        "w_up": ParamDef((mo.n_experts, d, mo.d_ff_expert),
                         ("experts", "expert_embed", "expert_ff")),
        "w_down": ParamDef((mo.n_experts, mo.d_ff_expert, d),
                           ("experts", "expert_ff", "expert_embed")),
    }
    if mo.n_shared_experts:
        defs.update(prefixed(mlp_defs(d, mo.d_ff_shared * mo.n_shared_experts),
                             "shared/"))
    return defs


def _positions_in_expert(expert_ids: jax.Array, n_experts: int) -> jax.Array:
    """Slot index of each (token,slot) within its expert's capacity queue.

    Sort-based (Megablocks-style): O(N log N), no (N, E) materialization.
    """
    n = expert_ids.shape[0]
    order = jnp.argsort(expert_ids, stable=True)
    sorted_e = expert_ids[order]
    idx = jnp.arange(n, dtype=jnp.int32)
    seg_start = jnp.concatenate([jnp.ones(1, bool), sorted_e[1:] != sorted_e[:-1]])
    start_idx = jnp.where(seg_start, idx, 0)
    seg_base = jax.lax.associative_scan(jnp.maximum, start_idx)
    pos_sorted = idx - seg_base
    pos = jnp.zeros(n, jnp.int32).at[order].set(pos_sorted)
    return pos


def moe_apply(cfg: ModelConfig, p: dict, prefix: str, x: jax.Array,
              groups: int | None = None, dense_eval: bool = False):
    """x: (B, T, d) -> (y, aux_loss).

    ``groups=None`` is the flat baseline: one global capacity buffer, whose
    token scatter GSPMD realizes as a *replicated buffer + all-reduce* —
    the dominant collective in the MoE train cells (EXPERIMENTS.md §Perf).
    ``groups=G`` (GShard-style grouped dispatch, G aligned with the batch
    shards) keeps every scatter and every position-sort local to its data
    shard; cross-device traffic collapses to the standard TP all-reduce of
    the combined output.
    """
    mo = cfg.moe
    B, T, d = x.shape
    dtype = x.dtype
    n_tok = B * T
    G = groups or 1
    assert n_tok % G == 0, (n_tok, G)
    ng = n_tok // G
    xg = x.reshape(G, ng, d)

    logits = (xg @ p[prefix + "router"].astype(dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (G, ng, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, mo.top_k)  # (G, ng, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    if dense_eval:
        # tiny-expert fast path: evaluate ALL experts for all tokens and
        # combine with the (zero-masked) gate matrix.  top_k/E more FLOPs,
        # but no capacity buffers, no sorts, no dispatch collectives —
        # and no token drops.  Wins whenever the cell is dispatch-bound
        # and compute has headroom (granite: 40 experts x d_ff 512).
        gates = jnp.zeros((G, ng, mo.n_experts), dtype)
        for s in range(mo.top_k):
            gates = gates.at[
                jnp.arange(G)[:, None], jnp.arange(ng)[None, :],
                expert_ids[..., s]].add(gate_vals[..., s].astype(dtype))
        h_g = jnp.einsum("gnd,edf->gnef", xg, p[prefix + "w_gate"].astype(dtype))
        h_u = jnp.einsum("gnd,edf->gnef", xg, p[prefix + "w_up"].astype(dtype))
        y = jnp.einsum("gnef,efd,gne->gnd", jax.nn.silu(h_g) * h_u,
                       p[prefix + "w_down"].astype(dtype), gates)
        if mo.n_shared_experts:
            y = y + mlp_apply(p, prefix + "shared/", xg, dtype)
        me = probs.reshape(n_tok, mo.n_experts).mean(0)
        ce = jnp.zeros(mo.n_experts, jnp.float32)
        ce = ce.at[expert_ids.reshape(-1)].add(1.0 / (n_tok * mo.top_k))
        aux = mo.n_experts * jnp.sum(me * ce) * mo.router_aux_weight
        return y.reshape(B, T, d), aux

    capacity = max(1, int(math.ceil(ng * mo.top_k / mo.n_experts
                                    * mo.capacity_factor)))
    flat_ids = expert_ids.reshape(G, ng * mo.top_k)  # token-major per group
    pos = jax.vmap(_positions_in_expert, in_axes=(0, None))(
        flat_ids, mo.n_experts).reshape(G, ng, mo.top_k)
    keep = pos < capacity

    # dispatch: one (vmapped-over-groups) scatter per top-k slot
    buf = jnp.zeros((G, mo.n_experts, capacity, d), dtype)

    def scatter_group(b, e_s, p_s, src):
        return b.at[e_s, p_s].add(src, mode="drop")

    for s in range(mo.top_k):
        e_s, p_s, k_s = expert_ids[..., s], pos[..., s], keep[..., s]
        src = jnp.where(k_s[..., None], xg, 0)
        p_c = jnp.where(k_s, p_s, capacity)  # dropped -> OOB (ignored)
        buf = jax.vmap(scatter_group)(buf, e_s, p_c, src)

    # expert ffn: (G, E, C, d) x (E, d, f) batched matmuls -> MXU
    g = jnp.einsum("gecd,edf->gecf", buf, p[prefix + "w_gate"].astype(dtype))
    u = jnp.einsum("gecd,edf->gecf", buf, p[prefix + "w_up"].astype(dtype))
    out_buf = jnp.einsum("gecf,efd->gecd", jax.nn.silu(g) * u,
                         p[prefix + "w_down"].astype(dtype))

    # combine: decoupled-accumulator merge (gather + weighted sum)
    y = jnp.zeros((G, ng, d), dtype)

    def gather_group(ob, e_s, p_s):
        return ob[e_s, p_s]

    for s in range(mo.top_k):
        e_s, p_s, k_s = expert_ids[..., s], pos[..., s], keep[..., s]
        contrib = jax.vmap(gather_group)(
            out_buf, e_s, jnp.minimum(p_s, capacity - 1))
        w = jnp.where(k_s, gate_vals[..., s], 0).astype(dtype)
        y = y + contrib * w[..., None]

    if mo.n_shared_experts:
        y = y + mlp_apply(p, prefix + "shared/", xg, dtype)

    # load-balancing aux loss (Switch-style, global means)
    me = probs.reshape(n_tok, mo.n_experts).mean(0)
    ce = jnp.zeros(mo.n_experts, jnp.float32)
    ce = ce.at[flat_ids.reshape(-1)].add(1.0 / (n_tok * mo.top_k))
    aux = mo.n_experts * jnp.sum(me * ce) * mo.router_aux_weight
    return y.reshape(B, T, d), aux
