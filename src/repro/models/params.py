"""Declarative parameter framework: one table drives init AND sharding specs.

``ParamDef`` describes shape + logical axes + initializer for every weight;
``init_params`` materializes arrays (jit-friendly), ``param_specs`` maps the
same table through the sharding rules — so the two can never drift.

Params live in a flat dict ``{"path/like/this": array}``.  Per-layer stacks
(for ``lax.scan`` over layers) get a leading ``L`` dim via :func:`stacked`.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding.rules import Rules


class ParamDef(NamedTuple):
    shape: tuple
    logical: tuple  # logical axis name per dim (see sharding.rules)
    init: str = "normal"  # normal | zeros | ones | embed
    scale: float | None = None  # stddev override


def stacked(defs: dict[str, ParamDef], n: int, prefix: str = "") -> dict[str, ParamDef]:
    """Prepend a layer-stack dim to every def (for scan-over-layers)."""
    out = {}
    for k, d in defs.items():
        out[prefix + k] = ParamDef((n, *d.shape), ("layers", *d.logical), d.init, d.scale)
    return out


def prefixed(defs: dict[str, ParamDef], prefix: str) -> dict[str, ParamDef]:
    return {prefix + k: v for k, v in defs.items()}


def init_params(defs: dict[str, ParamDef], key: jax.Array, dtype=jnp.float32):
    """Materialize all params (deterministic per-path keys; jittable)."""
    params = {}
    for path in sorted(defs):
        d = defs[path]
        k = jax.random.fold_in(key, _path_hash(path))
        if d.init == "zeros":
            params[path] = jnp.zeros(d.shape, dtype)
        elif d.init == "ones":
            params[path] = jnp.ones(d.shape, dtype)
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            std = d.scale if d.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
            params[path] = (jax.random.normal(k, d.shape, dtype) * std).astype(dtype)
    return params


def param_specs(defs: dict[str, ParamDef], rules: Rules):
    return {path: rules.spec(d.logical) for path, d in defs.items()}


def abstract_params(defs: dict[str, ParamDef], dtype=jnp.float32):
    return {p: jax.ShapeDtypeStruct(d.shape, dtype) for p, d in defs.items()}


def _path_hash(path: str) -> int:
    h = 2166136261
    for ch in path.encode():
        h = ((h ^ ch) * 16777619) & 0x7FFFFFFF
    return h


def count_params(defs: dict[str, ParamDef]) -> int:
    return int(sum(np.prod(d.shape) for d in defs.values()))
