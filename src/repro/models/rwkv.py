"""RWKV6 ("Finch") block: time-mix with data-dependent per-channel decay.

Recurrence per head (state S in R^{Dk x Dv}):

    o_t = r_t^T (S_{t-1} + (u ⊙ k_t) v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T,     w_t = exp(-exp(w0 + lora(x_t)))

Training uses a chunked linear-attention formulation: within a chunk of
length L the pairwise decay factors are factorized as
``(r_t ⊙ e^{E_t}) · (k_s ⊙ e^{-Λ_s})`` with cumulative log decays clamped to
±CLAMP for fp32 stability (contributions below e^-30 are numerically zero);
across chunks a ``lax.scan`` carries the state.  Decode uses the exact
one-step recurrence.  ``tests/test_rwkv.py`` checks chunked vs recurrent.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..config.base import ModelConfig
from .layers import rms_norm
from .params import ParamDef

CLAMP = 30.0


def rwkv_defs(cfg: ModelConfig) -> dict[str, ParamDef]:
    r = cfg.rwkv
    d = cfg.d_model
    return {
        # time-mix
        "mu_r": ParamDef((d,), (None,), "zeros"),
        "mu_k": ParamDef((d,), (None,), "zeros"),
        "mu_v": ParamDef((d,), (None,), "zeros"),
        "mu_w": ParamDef((d,), (None,), "zeros"),
        "mu_g": ParamDef((d,), (None,), "zeros"),
        "wr": ParamDef((d, d), ("embed", "heads_flat")),
        "wk": ParamDef((d, d), ("embed", "heads_flat")),
        "wv": ParamDef((d, d), ("embed", "heads_flat")),
        "wg": ParamDef((d, d), ("embed", "heads_flat")),
        "wo": ParamDef((d, d), ("heads_flat", "embed")),
        "w0": ParamDef((d,), (None,), "zeros"),
        "wA": ParamDef((d, r.decay_lora), ("embed", "lora")),
        "wB": ParamDef((r.decay_lora, d), ("lora", None)),
        "u": ParamDef((d,), (None,), "zeros"),
        "ln_x": ParamDef((d,), (None,), "ones"),
        # channel-mix
        "mu_k_cm": ParamDef((d,), (None,), "zeros"),
        "mu_r_cm": ParamDef((d,), (None,), "zeros"),
        "wk_cm": ParamDef((d, cfg.d_ff), ("embed", "ff")),
        "wv_cm": ParamDef((cfg.d_ff, d), ("ff", "embed")),
        "wr_cm": ParamDef((d, d), ("embed", "heads_flat")),
    }


def _shift(x, prev=None):
    """token shift: x_{t-1} with x_{-1} = prev (or 0)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    else:
        prev = prev[:, None].astype(x.dtype)
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def wkv_chunked(r, k, v, w_log, u, chunk, state0=None):
    """r,k,v,w_log: (B,T,H,D); u: (H,D). Returns (o, final_state (B,H,D,D))."""
    B, T, H, D = r.shape
    L = min(chunk, T)
    nc = T // L
    rs = r.astype(jnp.float32).reshape(B, nc, L, H, D)
    ks = k.astype(jnp.float32).reshape(B, nc, L, H, D)
    vs = v.astype(jnp.float32).reshape(B, nc, L, H, D)
    ws = w_log.astype(jnp.float32).reshape(B, nc, L, H, D)
    if state0 is None:
        state0 = jnp.zeros((B, H, D, D), jnp.float32)

    tri = jnp.tril(jnp.ones((L, L), bool), -1)  # strict lower: s < t

    def step(S, xs):
        rc, kc, vc, wc = xs  # (B,L,H,D)
        lam = jnp.cumsum(wc, axis=1)  # inclusive cumulative log decay Λ_t
        lam_ex = lam - wc  # exclusive: E_t = Λ_{t-1}
        # intra-chunk decays as PAIRWISE differences (always <= 0 for s < t,
        # so exp never overflows; factorized e^{E_t}·e^{-Λ_s} would corrupt
        # under saturating decay once both factors clamp).
        diff = lam_ex[:, :, None] - lam[:, None, :]  # (B, L(t), L(s), H, D)
        dmat = jnp.exp(jnp.minimum(diff, 0.0)) * tri[None, :, :, None, None]
        A = jnp.einsum("blhd,bshd,blshd->bhls", rc, kc, dmat)
        o_intra = jnp.einsum("bhls,bshd->blhd", A, vc)
        bonus = jnp.einsum("blhd,blhd->blh", rc, u[None, None] * kc)
        o_intra = o_intra + bonus[..., None] * vc
        o_inter = jnp.einsum("blhd,bhdv->blhv", rc * jnp.exp(lam_ex), S)
        # state update: S' = diag(e^{Λ_L}) S + Σ_s (k_s e^{Λ_L - Λ_s}) v_s^T
        tail = jnp.exp(lam[:, -1:] - lam)  # (B,L,H,D), exponent <= 0
        decay_all = jnp.exp(lam[:, -1])  # (B,H,D), exponent <= 0
        S_new = (decay_all[..., None] * S
                 + jnp.einsum("bshd,bshv->bhdv", kc * tail, vc))
        return S_new, o_intra + o_inter

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rs, ks, vs, ws))
    S_fin, os_ = jax.lax.scan(step, state0, xs)
    o = jnp.moveaxis(os_, 0, 1).reshape(B, T, H, D)
    return o, S_fin


def wkv_recurrent(r, k, v, w_log, u, state0=None):
    """Exact per-step recurrence (oracle + decode path)."""
    B, T, H, D = r.shape
    if state0 is None:
        state0 = jnp.zeros((B, H, D, D), jnp.float32)

    def step(S, xs):
        rt, kt, vt, wt = (t.astype(jnp.float32) for t in xs)  # (B,H,D)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,Dk,Dv)
        o = jnp.einsum("bhd,bhdv->bhv", rt, S + u[None, :, :, None] * kv)
        S_new = jnp.exp(wt)[..., None] * S + kv
        return S_new, o

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w_log))
    S_fin, os_ = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(os_, 0, 1), S_fin


def time_mix_apply(cfg: ModelConfig, p: dict, prefix: str, x: jax.Array,
                   cache: dict | None = None):
    r_cfg = cfg.rwkv
    B, T, d = x.shape
    H, D = d // r_cfg.head_dim, r_cfg.head_dim
    dtype = x.dtype

    prev = cache.get("x_tm") if cache else None
    xs = _shift(x, prev)

    def mix(mu):
        m = p[prefix + mu].astype(dtype)
        return x + m * (xs - x)

    r = (mix("mu_r") @ p[prefix + "wr"].astype(dtype)).reshape(B, T, H, D)
    k = (mix("mu_k") @ p[prefix + "wk"].astype(dtype)).reshape(B, T, H, D)
    v = (mix("mu_v") @ p[prefix + "wv"].astype(dtype)).reshape(B, T, H, D)
    g = jax.nn.silu(mix("mu_g") @ p[prefix + "wg"].astype(dtype))
    xw = mix("mu_w")
    w_raw = (p[prefix + "w0"].astype(jnp.float32)
             + (jnp.tanh(xw @ p[prefix + "wA"].astype(dtype)).astype(jnp.float32)
                @ p[prefix + "wB"].astype(jnp.float32)))
    w_log = -jnp.exp(jnp.clip(w_raw, -20.0, 10.0)).reshape(B, T, H, D)
    u = p[prefix + "u"].astype(jnp.float32).reshape(H, D)

    state0 = cache.get("state") if cache else None
    if T == 1 and cache is not None:
        o, S = wkv_recurrent(r, k, v, w_log, u, state0)
    else:
        o, S = wkv_chunked(r, k, v, w_log, u, r_cfg.chunk, state0)

    o = o.reshape(B, T, d).astype(dtype)
    o = rms_norm(o, p[prefix + "ln_x"], cfg.norm_eps) * g
    out = o @ p[prefix + "wo"].astype(dtype)
    new_cache = None
    if cache is not None:
        new_cache = {"x_tm": x[:, -1], "state": S}
    return out, new_cache


def channel_mix_apply(cfg: ModelConfig, p: dict, prefix: str, x: jax.Array,
                      cache: dict | None = None):
    dtype = x.dtype
    prev = cache.get("x_cm") if cache else None
    xs = _shift(x, prev)
    mk = p[prefix + "mu_k_cm"].astype(dtype)
    mr = p[prefix + "mu_r_cm"].astype(dtype)
    xk = x + mk * (xs - x)
    xr = x + mr * (xs - x)
    k = jnp.square(jax.nn.relu(xk @ p[prefix + "wk_cm"].astype(dtype)))
    v = k @ p[prefix + "wv_cm"].astype(dtype)
    r = jax.nn.sigmoid(xr @ p[prefix + "wr_cm"].astype(dtype))
    out = r * v
    new_cache = {"x_cm": x[:, -1]} if cache is not None else None
    return out, new_cache
