"""Composable decoder stack: builds any assigned architecture from its config.

Families
  * dense / moe / vlm / audio : pre-norm blocks of (GQA|MLA) attention + (MLP|MoE)
  * rwkv                      : time-mix + channel-mix blocks
  * hybrid (zamba2)           : Mamba2 backbone with one *weight-shared*
                                attention block invoked every 6th layer —
                                structured as a scan over 6 super-blocks of
                                [6 mamba + shared-attn], plus 2 tail layers.

Layers are ``lax.scan``-ned over stacked parameters so HLO size (and dry-run
compile time) is depth-independent; heterogeneous pieces (deepseek-v2's
leading dense layer, zamba2's shared block) live outside the stack.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..config.base import ModelConfig, RunConfig
from . import attention as attn_mod
from . import moe as moe_mod
from . import rwkv as rwkv_mod
from . import ssm as ssm_mod
from .attention import AttnCache, MLACache, attn_defs, gqa_apply, mla_apply, mla_defs
from .layers import mlp_apply, mlp_defs, rms_norm
from .params import ParamDef, init_params, param_specs, prefixed, stacked


# ----------------------------------------------------------------------------
# parameter tables
# ----------------------------------------------------------------------------

def _block_defs(cfg: ModelConfig, *, use_moe: bool) -> dict[str, ParamDef]:
    defs = {"ln1": ParamDef((cfg.d_model,), (None,), "ones"),
            "ln2": ParamDef((cfg.d_model,), (None,), "ones")}
    a_defs = mla_defs(cfg) if cfg.mla is not None else attn_defs(cfg)
    defs.update(prefixed(a_defs, "attn/"))
    if use_moe:
        defs.update(prefixed(moe_mod.moe_defs(cfg), "moe/"))
    else:
        defs.update(prefixed(mlp_defs(cfg.d_model, cfg.d_ff), "mlp/"))
    return defs


def _rwkv_block_defs(cfg: ModelConfig) -> dict[str, ParamDef]:
    defs = {"ln1": ParamDef((cfg.d_model,), (None,), "ones"),
            "ln2": ParamDef((cfg.d_model,), (None,), "ones")}
    defs.update(prefixed(rwkv_mod.rwkv_defs(cfg), "mix/"))
    return defs


def _mamba_block_defs(cfg: ModelConfig) -> dict[str, ParamDef]:
    defs = {"ln": ParamDef((cfg.d_model,), (None,), "ones")}
    defs.update(prefixed(ssm_mod.ssm_defs(cfg), "ssm/"))
    return defs


def zamba_plan(cfg: ModelConfig) -> tuple[int, int, int]:
    """(n_super, per_super, n_tail) for the hybrid stack."""
    per = cfg.ssm.attn_every
    n_super = cfg.n_layers // per
    n_tail = cfg.n_layers - n_super * per
    return n_super, per, n_tail


def model_defs(cfg: ModelConfig) -> dict[str, ParamDef]:
    d = cfg.d_model
    defs = {
        "embed": ParamDef((cfg.vocab_size, d), ("vocab", "embed"), "normal", 0.02),
        "final_ln": ParamDef((d,), (None,), "ones"),
    }
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamDef((d, cfg.vocab_size), ("embed", "vocab"))
    if cfg.rwkv is not None:
        defs.update(stacked(_rwkv_block_defs(cfg), cfg.n_layers, "layers/"))
    elif cfg.ssm is not None:
        n_super, per, n_tail = zamba_plan(cfg)
        defs.update(stacked(_mamba_block_defs(cfg), n_super * per, "layers/"))
        for t in range(n_tail):
            defs.update(prefixed(_mamba_block_defs(cfg), f"tail{t}/"))
        defs.update(prefixed(_block_defs(cfg, use_moe=False), "shared/"))
    else:
        use_moe = cfg.moe is not None
        first_dense = cfg.moe.first_dense_layers if use_moe else 0
        n_stacked = cfg.n_layers - first_dense
        defs.update(stacked(_block_defs(cfg, use_moe=use_moe), n_stacked, "layers/"))
        for i in range(first_dense):
            defs.update(prefixed(_block_defs(cfg, use_moe=False), f"dense{i}/"))
    return defs


def init_model(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32):
    return init_params(model_defs(cfg), key, dtype)


def model_specs(cfg: ModelConfig, rules):
    return param_specs(model_defs(cfg), rules)


# ----------------------------------------------------------------------------
# block bodies
# ----------------------------------------------------------------------------

def _subtree(p: dict, prefix: str) -> dict:
    n = len(prefix)
    return {k[n:]: v for k, v in p.items() if k.startswith(prefix)}


def _attn_mlp_block(cfg, run, p, x, positions, cache, cache_pos, *, use_moe):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    apply = mla_apply if cfg.mla is not None else gqa_apply
    h, new_cache = apply(cfg, run, p, "attn/", h, positions, cache, cache_pos)
    x = x + h
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if use_moe:
        h, aux = moe_mod.moe_apply(cfg, p, "moe/", h,
                                   groups=getattr(run, "moe_groups", None),
                                   dense_eval=getattr(run, "moe_dense_eval",
                                                      False))
    else:
        h, aux = mlp_apply(p, "mlp/", h, x.dtype), jnp.float32(0)
    return x + h, new_cache, aux


def _rwkv_block(cfg, p, x, cache):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    h, c1 = rwkv_mod.time_mix_apply(cfg, p, "mix/", h, cache)
    x = x + h
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    h, c2 = rwkv_mod.channel_mix_apply(cfg, p, "mix/", h, cache)
    new_cache = None if cache is None else {**c1, **c2}
    return x + h, new_cache


def _mamba_block(cfg, p, x, cache):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    h, new_cache = ssm_mod.ssm_apply(cfg, p, "ssm/", h, cache)
    return x + h, new_cache


# ----------------------------------------------------------------------------
# cache construction
# ----------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    """Decode cache pytree (zeros). Layout mirrors forward()'s expectations."""
    d = cfg.d_model
    if cfg.rwkv is not None:
        H, D = d // cfg.rwkv.head_dim, cfg.rwkv.head_dim
        L = cfg.n_layers
        return {
            "state": jnp.zeros((L, batch, H, D, D), jnp.float32),
            "x_tm": jnp.zeros((L, batch, d), dtype),
            "x_cm": jnp.zeros((L, batch, d), dtype),
        }
    if cfg.ssm is not None:
        s = cfg.ssm
        n_super, per, n_tail = zamba_plan(cfg)
        di = s.expand * d
        H = di // s.head_dim
        gn = s.n_groups * s.d_state
        kvf = cfg.n_kv_heads * cfg.resolved_head_dim

        def mamba_cache(lead):
            return {
                "conv_x": jnp.zeros((*lead, batch, s.conv_width - 1, di), dtype),
                "conv_B": jnp.zeros((*lead, batch, s.conv_width - 1, gn), dtype),
                "conv_C": jnp.zeros((*lead, batch, s.conv_width - 1, gn), dtype),
                "state": jnp.zeros((*lead, batch, H, s.head_dim, gn), jnp.float32),
            }

        return {
            "mamba": mamba_cache((n_super, per)),
            "attn": AttnCache(
                k=jnp.zeros((n_super, batch, max_seq, kvf), dtype),
                v=jnp.zeros((n_super, batch, max_seq, kvf), dtype),
                pos=jnp.full((n_super, batch, max_seq), 2**30, jnp.int32)),
            "tail": [mamba_cache(()) for _ in range(n_tail)],
        }
    if cfg.mla is not None:
        m = cfg.mla
        L = cfg.n_layers - cfg.moe.first_dense_layers if cfg.moe else cfg.n_layers
        cache = {"layers": MLACache(
            ckv=jnp.zeros((L, batch, max_seq, m.kv_lora_rank), dtype),
            krope=jnp.zeros((L, batch, max_seq, m.rope_head_dim), dtype),
            pos=jnp.full((L, batch, max_seq), 2**30, jnp.int32))}
        first = cfg.moe.first_dense_layers if cfg.moe else 0
        for i in range(first):
            cache[f"dense{i}"] = MLACache(
                ckv=jnp.zeros((batch, max_seq, m.kv_lora_rank), dtype),
                krope=jnp.zeros((batch, max_seq, m.rope_head_dim), dtype),
                pos=jnp.full((batch, max_seq), 2**30, jnp.int32))
        return cache
    kvf = cfg.n_kv_heads * cfg.resolved_head_dim
    seq = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
    first = cfg.moe.first_dense_layers if cfg.moe else 0
    L = cfg.n_layers - first
    cache = {"layers": AttnCache(k=jnp.zeros((L, batch, seq, kvf), dtype),
                                 v=jnp.zeros((L, batch, seq, kvf), dtype),
                                 pos=jnp.full((L, batch, seq), 2**30, jnp.int32))}
    for i in range(first):
        cache[f"dense{i}"] = AttnCache(k=jnp.zeros((batch, seq, kvf), dtype),
                                       v=jnp.zeros((batch, seq, kvf), dtype),
                                       pos=jnp.full((batch, seq), 2**30, jnp.int32))
    return cache


def cache_logical(cfg: ModelConfig, batch_shardable: bool, seq_shard: bool):
    """Logical axes per cache leaf (same structure as init_cache output)."""
    b = "batch" if batch_shardable else None
    s = "kv_seq" if seq_shard else None
    if cfg.rwkv is not None:
        return {"state": ("layers", b, None, None, None),
                "x_tm": ("layers", b, None), "x_cm": ("layers", b, None)}
    if cfg.ssm is not None:
        n_super, per, n_tail = zamba_plan(cfg)

        def mamba_log(extra):
            return {"conv_x": (*extra, b, None, "ff"),
                    "conv_B": (*extra, b, None, None),
                    "conv_C": (*extra, b, None, None),
                    "state": (*extra, b, None, None, None)}

        return {"mamba": mamba_log(("layers", None)),
                "attn": AttnCache(k=("layers", b, s, "kv_flat"),
                                  v=("layers", b, s, "kv_flat"),
                                  pos=("layers", b, s)),
                "tail": [mamba_log(()) for _ in range(n_tail)]}
    if cfg.mla is not None:
        first = cfg.moe.first_dense_layers if cfg.moe else 0
        sm = "mla_seq"  # compressed KV shards over seq on the model axis
        out = {"layers": MLACache(ckv=("layers", b, sm, None),
                                  krope=("layers", b, sm, None),
                                  pos=("layers", b, sm))}
        for i in range(first):
            out[f"dense{i}"] = MLACache(ckv=(b, sm, None), krope=(b, sm, None),
                                        pos=(b, sm))
        return out
    first = cfg.moe.first_dense_layers if cfg.moe else 0
    out = {"layers": AttnCache(k=("layers", b, s, "kv_flat"),
                               v=("layers", b, s, "kv_flat"),
                               pos=("layers", b, s))}
    for i in range(first):
        out[f"dense{i}"] = AttnCache(k=(b, s, "kv_flat"), v=(b, s, "kv_flat"),
                                     pos=(b, s))
    return out


# ----------------------------------------------------------------------------
# forward
# ----------------------------------------------------------------------------

def make_forward(cfg: ModelConfig, run: RunConfig, mesh=None, rules=None):
    """Returns forward(params, tokens, positions, prefix_embeds, cache,
    cache_pos) -> (logits, new_cache, aux)."""

    def constrain(x, logical):
        if mesh is None or rules is None:
            return x
        from ..sharding.rules import constrain as _c
        return _c(x, mesh, rules, logical)

    remat = run.remat != "none"
    policy = None
    if run.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims

    def maybe_ckpt(fn):
        if remat:
            return jax.checkpoint(fn, policy=policy, prevent_cse=False)
        return fn

    batch_logical = ("batch", "seq", "act_embed")

    def forward(params, tokens, positions, prefix_embeds=None, cache=None,
                cache_pos=None, decode=False):
        dtype = jnp.dtype(run.compute_dtype)
        x = params["embed"].astype(dtype)[tokens]
        if prefix_embeds is not None:
            x = jnp.concatenate([prefix_embeds.astype(dtype), x], axis=1)
            P = prefix_embeds.shape[1]
            ppos = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32)[None],
                                    (x.shape[0], P))
            positions = jnp.concatenate([ppos, positions + P], axis=1)
        x = constrain(x, batch_logical)
        aux_total = jnp.float32(0)
        new_cache = {} if cache is not None else None
        cp = cache_pos if cache_pos is not None else 0

        if cfg.rwkv is not None:
            lp = _subtree(params, "layers/")

            def body(carry, xs):
                x, aux = carry
                p_l, c_l = xs
                x, nc = _rwkv_block(cfg, p_l, x, c_l)
                x = constrain(x, batch_logical)
                return (x, aux), nc

            body = maybe_ckpt(body)
            cs = cache if cache is not None else None
            (x, aux_total), ncs = jax.lax.scan(body, (x, aux_total), (lp, cs))
            if cache is not None:
                new_cache = ncs
        elif cfg.ssm is not None:
            n_super, per, n_tail = zamba_plan(cfg)
            lp = _subtree(params, "layers/")
            lp_super = jax.tree.map(
                lambda a: a.reshape(n_super, per, *a.shape[1:]), lp)
            sp = _subtree(params, "shared/")

            def super_body(carry, xs):
                x, aux = carry
                p_s, c_mamba, c_attn = xs

                def mamba_body(carry2, xs2):
                    x2, = carry2
                    p_l, c_l = xs2
                    x2, nc = _mamba_block(cfg, p_l, x2, c_l)
                    return (x2,), nc

                (x,), nc_mamba = jax.lax.scan(mamba_body, (x,), (p_s, c_mamba))
                x, nc_attn, aux_l = _attn_mlp_block(
                    cfg, run, sp, x, positions, c_attn, cp, use_moe=False)
                x = constrain(x, batch_logical)
                return (x, aux + aux_l), (nc_mamba, nc_attn)

            super_body = maybe_ckpt(super_body)
            c_mamba = cache["mamba"] if cache is not None else None
            c_attn = cache["attn"] if cache is not None else None
            (x, aux_total), (ncm, nca) = jax.lax.scan(
                super_body, (x, aux_total), (lp_super, c_mamba, c_attn))
            for t in range(n_tail):
                tp = _subtree(params, f"tail{t}/")
                c_t = cache["tail"][t] if cache is not None else None
                x, nct = _mamba_block(cfg, tp, x, c_t)
                if cache is not None:
                    new_cache.setdefault("tail", []).append(nct)
            if cache is not None:
                new_cache.update({"mamba": ncm, "attn": nca})
                new_cache.setdefault("tail", [])
        else:
            use_moe = cfg.moe is not None
            first = cfg.moe.first_dense_layers if use_moe else 0
            for i in range(first):
                dp = _subtree(params, f"dense{i}/")
                c_i = cache[f"dense{i}"] if cache is not None else None
                x, nci, aux_l = _attn_mlp_block(cfg, run, dp, x, positions,
                                                c_i, cp, use_moe=False)
                aux_total += aux_l
                if cache is not None:
                    new_cache[f"dense{i}"] = nci
            lp = _subtree(params, "layers/")

            def body(carry, xs):
                x, aux = carry
                p_l, c_l = xs
                x, nc, aux_l = _attn_mlp_block(cfg, run, p_l, x, positions,
                                               c_l, cp, use_moe=use_moe)
                x = constrain(x, batch_logical)
                return (x, aux + aux_l), nc

            body = maybe_ckpt(body)
            c_layers = cache["layers"] if cache is not None else None
            (x, aux_total), ncs = jax.lax.scan(body, (x, aux_total),
                                               (lp, c_layers))
            if cache is not None:
                new_cache["layers"] = ncs

        x = rms_norm(x, params["final_ln"], cfg.norm_eps)
        unembed = (params["embed"].T if cfg.tie_embeddings
                   else params["unembed"])
        logits = (x.astype(jnp.float32) @ unembed.astype(jnp.float32))
        logits = constrain(logits, ("batch", "seq", "logit_vocab"))
        return logits, new_cache, aux_total

    return forward
