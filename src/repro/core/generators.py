"""Synthetic graph generators (the data substrate for census experiments).

The paper evaluates on five real-world networks (Table 4.1).  Those datasets
cannot ship inside this offline container, so we provide:

  * ``erdos_renyi``   — uniform random digraphs,
  * ``rmat``          — Kronecker/R-MAT power-law digraphs (the standard
                        stand-in for "small-world, skewed degree" networks
                        such as Patents/Google/Slashdot),
  * ``paper_profile`` — R-MAT instances whose (n, m) match scaled-down
                        versions of the paper's Table 4.1 datasets,

plus ``load_pajek_or_edgelist`` in :mod:`repro.core.graph` for real files on
a real cluster.
"""
from __future__ import annotations

import numpy as np

from .graph import CSRGraph, from_edges

# (vertices, arcs, directed) from Table 4.1 of the paper.
PAPER_DATASETS: dict[str, tuple[int, int, bool]] = {
    "actors": (520_223, 2_940_808, False),
    "patents": (3_774_768, 16_518_948, True),
    "amazon": (403_394, 3_387_388, True),
    "slashdot": (82_144, 549_202, True),
    "google": (916_428, 5_105_039, True),
    "eatSR": (23_219, 325_589, True),
    "NDwww": (325_729, 1_497_135, True),
}


def erdos_renyi(n: int, m: int, seed: int = 0) -> CSRGraph:
    """Directed G(n, m): m arcs sampled uniformly without self-loops."""
    rng = np.random.default_rng(seed)
    # oversample to survive dedup/self-loop removal
    k = int(m * 1.3) + 16
    src = rng.integers(0, n, size=k, dtype=np.int64)
    dst = rng.integers(0, n, size=k, dtype=np.int64)
    keep = src != dst
    src, dst = src[keep][:m], dst[keep][:m]
    return from_edges(n, src, dst, directed=True)


def rmat(
    scale: int,
    edge_factor: int = 16,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
    directed: bool = True,
) -> CSRGraph:
    """R-MAT power-law digraph with 2**scale vertices (Graph500 defaults)."""
    n = 1 << scale
    m = n * edge_factor
    rng = np.random.default_rng(seed)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab, abc = a + b, a + b + c
    for bit in range(scale):
        r = rng.random(m)
        # quadrant choice per Chakrabarti et al.
        in_cd = r >= ab
        in_b_or_d = ((r >= a) & (r < ab)) | (r >= abc)
        src |= in_cd.astype(np.int64) << bit
        dst |= in_b_or_d.astype(np.int64) << bit
    # permute vertex ids to break the Kronecker locality artifact
    perm = rng.permutation(n).astype(np.int64)
    src, dst = perm[src], perm[dst]
    return from_edges(n, src, dst, directed=directed)


def paper_profile(name: str, scale_down: float = 64.0, seed: int = 0) -> CSRGraph:
    """R-MAT graph matching a Table 4.1 dataset's (n, m) shape, scaled down.

    ``scale_down`` divides both n and m so census experiments finish on the
    CPU container; on a real pod use ``scale_down=1``.
    """
    n, m, directed = PAPER_DATASETS[name]
    n_s = max(64, int(n / scale_down))
    m_s = max(128, int(m / scale_down))
    scale = max(6, int(np.ceil(np.log2(n_s))))
    ef = max(1, int(round(m_s / (1 << scale))))
    return rmat(scale, edge_factor=ef, seed=seed, directed=directed)
