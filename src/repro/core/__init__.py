"""Core library: the paper's Triad Census technique as a JAX module.

The public census entry point is :mod:`repro.engine`
(``compile_census(graph, CensusConfig(...)).run(graph)``); its names are
re-exported here lazily.  ``triad_census`` / ``distributed_triad_census``
remain as deprecated shims over the engine.
"""
from .census import (CensusResult, brute_force_census, canonical_dyads,
                     make_census_fn, triad_census)
from .balance import ShardedTasks, dyad_weights, exact_s_sizes, pack_tasks
from .delta import GraphDelta, affected_dyads, apply_delta_csr
from .distributed import distributed_triad_census, make_distributed_census_fn
from .graph import (CSRGraph, GraphArrays, arcs_host, arcs_host_iter,
                    from_edges, from_edges_mmap, load_pajek_or_edgelist,
                    stack_graph_arrays)
from .partition import (GraphPartition, partition_cuts, partition_graph,
                        shard_dyads)
from .reorder import (REORDER_STRATEGIES, compute_permutation,
                      inverse_permutation, locality_score, permute_graph)
from .triad_table import TRIAD_NAMES, TRIAD_TABLE_64

_ENGINE_EXPORTS = ("CensusConfig", "CensusPlan", "GraphMeta",
                   "clear_plan_cache", "compile_census", "plan_cache_stats")

__all__ = [
    "CensusResult", "CSRGraph", "GraphArrays", "GraphDelta",
    "REORDER_STRATEGIES", "ShardedTasks", "TRIAD_NAMES", "TRIAD_TABLE_64",
    "GraphPartition",
    "affected_dyads", "apply_delta_csr", "arcs_host", "arcs_host_iter",
    "brute_force_census",
    "canonical_dyads", "compute_permutation", "distributed_triad_census",
    "dyad_weights", "exact_s_sizes", "from_edges", "from_edges_mmap",
    "inverse_permutation",
    "load_pajek_or_edgelist", "locality_score", "make_census_fn",
    "make_distributed_census_fn", "pack_tasks", "partition_cuts",
    "partition_graph", "permute_graph", "shard_dyads",
    "stack_graph_arrays", "triad_census", *_ENGINE_EXPORTS,
]


def __getattr__(name):
    # lazy re-export: repro.engine itself imports repro.core submodules, so
    # an eager import here would be circular.
    if name in _ENGINE_EXPORTS:
        from .. import engine
        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
