"""Core library: the paper's Triad Census technique as a JAX module."""
from .census import (CensusResult, brute_force_census, canonical_dyads,
                     make_census_fn, triad_census)
from .balance import ShardedTasks, dyad_weights, exact_s_sizes, pack_tasks
from .distributed import distributed_triad_census, make_distributed_census_fn
from .graph import CSRGraph, GraphArrays, from_edges, load_pajek_or_edgelist
from .triad_table import TRIAD_NAMES, TRIAD_TABLE_64

__all__ = [
    "CensusResult", "CSRGraph", "GraphArrays", "ShardedTasks", "TRIAD_NAMES",
    "TRIAD_TABLE_64", "brute_force_census", "canonical_dyads",
    "distributed_triad_census", "dyad_weights", "exact_s_sizes", "from_edges",
    "load_pajek_or_edgelist", "make_census_fn", "make_distributed_census_fn",
    "pack_tasks", "triad_census",
]
