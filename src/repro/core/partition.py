"""Contiguous vertex-range graph partitioning with halo exchange (host side).

The paper's Patents-scale result (and the Cray-XMT comparison it anchors)
lives at sizes where the whole CSR cannot sit on one device, so the graph
itself — not just the dyad stream — must be sharded.  This module is the
host-side half of that subsystem: it cuts the vertex id space into
``parts`` contiguous ranges balanced by **owned canonical dyads**, and
builds, per shard, a *local* CSR holding the full rows of the owned range
plus a **halo** of remote rows its dyads read.

Why contiguous ranges: canonical dyads ``(u, v), v > u`` are enumerated
in row order, so a contiguous vertex range owns a contiguous span of the
canonical dyad stream — the cuts come straight out of a cumulative-sum +
``searchsorted`` over per-row owned-dyad counts, and a locality-aware
relabeling (``EngineConfig(reorder=...)``, applied upstream of
partitioning) doubles as a partitioner: neighbors relabeled close
together land in the same shard and shrink every halo.

Why the halo is exactly ``range ∪ partners ∪ N(range ∪ partners)``: every
chunk kernel's contribution for a dyad ``(u, v)`` reads only rows of
``{u, v} ∪ N(u) ∪ N(v)`` — the same locality contract
``GraphOp.delta_local`` declares for the incremental path (see
:mod:`repro.engine.ops`).  For owned dyads, ``u`` is in the range, ``v``
is a partner, and every probed third vertex ``w`` is a neighbor of one of
them; keeping those rows IN FULL (never truncated) means membership
probes see exactly the global CSR row and results are bit-identical to
the unpartitioned pass.  The in-arc tiles the pallas census path gathers
are covered too: an in-arc ``w -> u`` implies ``w ∈ N(u)``, so ``w``'s
full out-row is local and the shard-local transpose CSR is complete for
every kept row.

Everything here is plain numpy over host views of the graph arrays —
memory-mapped graphs (:func:`repro.core.graph.from_edges_mmap`) stream
through these routines one shard at a time without materializing the
full arc list in RAM.  Device-side execution lives in
:mod:`repro.engine.partition`.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .graph import CSRGraph, GraphArrays

__all__ = ["GraphPartition", "ShardInfo", "build_local_arrays",
           "halo_by_owner", "halo_vertices", "local_ptrs", "owned_idx",
           "partition_cuts", "partition_graph", "shard_dyads"]


def _host(a) -> np.ndarray:
    """Host view of a graph array: numpy (incl. ``np.memmap``) passes
    through untouched — slicing stays lazy for mmap-backed graphs — and a
    device array is fetched once."""
    return a if isinstance(a, np.ndarray) else np.asarray(a)


def partition_cuts(g: CSRGraph, parts: int) -> np.ndarray:
    """``parts + 1`` vertex boundaries with near-equal owned-dyad counts.

    Vertex ``u`` owns the canonical dyads ``(u, v), v > u, v ∈ N(u)``;
    cutting the cumulative owned-count curve at even targets balances the
    *work* (dyads), not the vertex count — the degree-skew analogue of
    the paper's dynamic scheduling, applied to data placement.  Returns
    a monotone int64 array ``[0, c_1, ..., c_{parts-1}, n]``; duplicate
    boundaries (an empty shard) are legal and skipped at execution.
    """
    parts = max(1, int(parts))
    ptr = _host(g.arrays.nbr_ptr)[: g.n + 1].astype(np.int64)
    idx = _host(g.arrays.nbr_idx)
    owned = np.zeros(g.n, dtype=np.int64)
    block = 1 << 18  # rows per sweep: bounded RAM even on mmap graphs
    for lo in range(0, g.n, block):
        hi = min(lo + block, g.n)
        a, b = int(ptr[lo]), int(ptr[hi])
        cols = np.asarray(idx[a:b], dtype=np.int64)
        rows = np.repeat(np.arange(lo, hi, dtype=np.int64),
                         np.diff(ptr[lo:hi + 1]))
        counts = np.bincount(rows[cols > rows] - lo, minlength=hi - lo)
        owned[lo:hi] = counts
    cum = np.concatenate([[0], np.cumsum(owned)])
    targets = cum[-1] * np.arange(1, parts, dtype=np.float64) / parts
    cuts = np.searchsorted(cum, targets, side="left")
    return np.concatenate([[0], cuts, [g.n]]).astype(np.int64)


def shard_dyads(g: CSRGraph, lo: int, hi: int):
    """Canonical dyads owned by the vertex range ``[lo, hi)``, in global
    ids and canonical (row-major) order — the contiguous span of the full
    stream this shard owns.  Reads only the range's CSR rows, so an
    mmap-backed graph pages in O(range) bytes."""
    ptr = _host(g.arrays.nbr_ptr)[: g.n + 1].astype(np.int64)
    a, b = int(ptr[lo]), int(ptr[hi])
    cols = np.asarray(_host(g.arrays.nbr_idx)[a:b])
    rows = np.repeat(np.arange(lo, hi, dtype=np.int32),
                     np.diff(ptr[lo:hi + 1]))
    keep = cols > rows
    return rows[keep].astype(np.int32), cols[keep].astype(np.int32)


def _gather_rows(ptr: np.ndarray, idx, verts: np.ndarray) -> np.ndarray:
    """Concatenated CSR rows of ``verts`` (sorted unique int64 ids),
    via one vectorized position expansion — no per-vertex python loop."""
    starts = ptr[verts]
    counts = ptr[verts + 1] - starts
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64)
    cum = np.concatenate([[0], np.cumsum(counts)])
    pos = np.repeat(starts - cum[:-1], counts) + np.arange(total)
    return np.asarray(idx[pos], dtype=np.int64)


def halo_vertices(g: CSRGraph, lo: int, hi: int,
                  partners: np.ndarray) -> np.ndarray:
    """Sorted remote row ids the shard ``[lo, hi)`` must keep locally.

    ``partners`` are the ``v`` endpoints of the shard's owned dyads.  The
    kernels read rows of ``{u, v} ∪ N(u) ∪ N(v)`` per dyad, so the halo
    is ``(partners ∪ N(range ∪ partners))`` minus the owned range —
    every membership probe target, neighborhood gather, and (via
    ``w ∈ N(u)``) every in-arc source row of the owned endpoints.
    """
    ptr = _host(g.arrays.nbr_ptr)[: g.n + 1].astype(np.int64)
    own = np.arange(lo, hi, dtype=np.int64)
    ends = np.union1d(own, np.asarray(partners, dtype=np.int64))
    third = _gather_rows(ptr, _host(g.arrays.nbr_idx), ends)
    needed = np.union1d(ends, third)
    return needed[(needed < lo) | (needed >= hi)]


def halo_by_owner(cuts: np.ndarray, halo: np.ndarray) -> "list[tuple[int, np.ndarray]]":
    """Group a shard's halo row ids by their OWNER shard — the ownership
    metadata the device-side halo exchange routes on.

    Contiguous vertex-range ownership makes this a ``searchsorted`` over
    the cuts: halo id ``w`` is owned by the shard whose range contains it,
    and because ``halo`` is sorted, each owner's ids form one contiguous
    slice.  Returns ``[(owner_index, ids), ...]`` for owners with at
    least one requested row, in owner order — each entry is one
    (requester, owner) exchange: the owner's resident device arrays hold
    the rows in full, so the rows transfer device-to-device
    (``jax.device_put`` peer copy), never through the host."""
    halo = np.asarray(halo, dtype=np.int64)
    if len(halo) == 0:
        return []
    owner = np.searchsorted(np.asarray(cuts), halo, side="right") - 1
    bounds = np.flatnonzero(np.diff(owner)) + 1
    groups = np.split(halo, bounds)
    return [(int(owner[0 if i == 0 else bounds[i - 1]]), grp)
            for i, grp in enumerate(groups)]


def local_ptrs(g: CSRGraph, lo: int, hi: int, halo: np.ndarray):
    """The O(n) ptr half of a shard's local CSR — ``(out_ptr, nbr_ptr,
    nbr_deg)`` exactly as :func:`build_local_arrays` lays them out, but
    WITHOUT gathering any idx entries.

    The device-side halo exchange stages these host-derived ptr arrays
    (cheap, vertex-count-sized) and fills the idx arrays on device: the
    owned block from one host upload, every halo block from the owner
    shard's resident device rows.  The idx layout they describe is the
    concatenation of kept rows in vertex-id order, so the block of rows
    owned by shard ``o`` (range ``[lo_o, hi_o)``) occupies the contiguous
    span ``[ptr[lo_o], ptr[hi_o])`` of the compacted idx array —
    block offsets come straight off these ptrs."""
    keep = np.union1d(np.arange(lo, hi, dtype=np.int64),
                      np.asarray(halo, dtype=np.int64))

    def sub(ptr_full):
        ptr = _host(ptr_full)[: g.n + 1].astype(np.int64)
        counts = ptr[keep + 1] - ptr[keep]
        new_counts = np.zeros(g.n, dtype=np.int64)
        new_counts[keep] = counts
        return np.concatenate([[0], np.cumsum(new_counts)]).astype(np.int32)

    out_ptr = sub(g.arrays.out_ptr)
    nbr_ptr = sub(g.arrays.nbr_ptr)
    nbr_deg = (nbr_ptr[1:] - nbr_ptr[:-1]).astype(np.int32)
    return out_ptr, nbr_ptr, nbr_deg


def owned_idx(g: CSRGraph, lo: int, hi: int):
    """Concatenated idx entries of the OWNED rows ``[lo, hi)`` only —
    ``(out_block, nbr_block)`` int32 — the single host→device upload a
    pool-mode shard pays (1/P of the graph; halo blocks arrive
    device-to-device from their owners)."""
    verts = np.arange(lo, hi, dtype=np.int64)
    out = _gather_rows(_host(g.arrays.out_ptr)[: g.n + 1].astype(np.int64),
                       _host(g.arrays.out_idx), verts).astype(np.int32)
    nbr = _gather_rows(_host(g.arrays.nbr_ptr)[: g.n + 1].astype(np.int64),
                       _host(g.arrays.nbr_idx), verts).astype(np.int32)
    return out, nbr


def build_local_arrays(g: CSRGraph, lo: int, hi: int,
                       halo: np.ndarray) -> GraphArrays:
    """Shard-local CSR as host numpy: full-length ptr/deg arrays (rows
    outside ``range ∪ halo`` are empty — binary search sees ``lo == hi``
    and every probe of them misses, which no owned dyad ever does) over
    **compacted** idx arrays holding only the kept rows' entries.  Kept
    rows are bit-identical to the global CSR rows, so every kernel probe
    answers exactly as on the full graph."""
    keep = np.union1d(np.arange(lo, hi, dtype=np.int64),
                      np.asarray(halo, dtype=np.int64))

    def sub(ptr_full, idx_full):
        ptr = _host(ptr_full)[: g.n + 1].astype(np.int64)
        starts = ptr[keep]
        counts = ptr[keep + 1] - starts
        local_idx = _gather_rows(ptr, _host(idx_full), keep).astype(np.int32)
        new_counts = np.zeros(g.n, dtype=np.int64)
        new_counts[keep] = counts
        new_ptr = np.concatenate(
            [[0], np.cumsum(new_counts)]).astype(np.int32)
        return new_ptr, local_idx

    out_ptr, out_idx = sub(g.arrays.out_ptr, g.arrays.out_idx)
    nbr_ptr, nbr_idx = sub(g.arrays.nbr_ptr, g.arrays.nbr_idx)
    nbr_deg = (nbr_ptr[1:] - nbr_ptr[:-1]).astype(np.int32)
    return GraphArrays(out_ptr=out_ptr, out_idx=out_idx, nbr_ptr=nbr_ptr,
                       nbr_idx=nbr_idx, nbr_deg=nbr_deg)


@dataclasses.dataclass(frozen=True)
class ShardInfo:
    """Static per-shard metadata (the dyad lists and local CSR are rebuilt
    per run — a cached plan must never pin graph-sized host memory)."""

    index: int
    lo: int              # owned vertex range [lo, hi)
    hi: int
    n_dyads: int         # owned canonical dyads
    halo: np.ndarray     # sorted remote row ids kept locally
    m_out: int           # local out-CSR entries (owned ∪ halo rows)
    m_nbr: int           # local nbr-CSR entries

    @property
    def halo_size(self) -> int:
        return int(len(self.halo))


@dataclasses.dataclass(frozen=True)
class GraphPartition:
    """A graph's partition layout: cuts plus per-shard :class:`ShardInfo`.

    Built by :func:`partition_graph` (and memoized per (plan, graph) by
    the engine — see ``Plan._partition_memo``).  Holds metadata only:
    cuts, dyad counts, halo id lists and local array sizes — O(n) worst
    case, never O(m)."""

    parts: int
    cuts: np.ndarray
    shards: "tuple[ShardInfo, ...]"

    @property
    def dyad_counts(self) -> "list[int]":
        return [s.n_dyads for s in self.shards]

    @property
    def halo_sizes(self) -> "list[int]":
        return [s.halo_size for s in self.shards]

    @property
    def max_dyads(self) -> int:
        return max([s.n_dyads for s in self.shards] or [0])


def partition_graph(g: CSRGraph, parts: int) -> GraphPartition:
    """Cut ``g`` into ``parts`` contiguous vertex-range shards with halos.

    One pass per shard over its owned rows + halo rows; the returned
    layout is all an executor needs to rebuild any shard's local CSR
    independently (out-of-core: one shard resident at a time)."""
    cuts = partition_cuts(g, parts)
    ptrs = (_host(g.arrays.out_ptr)[: g.n + 1].astype(np.int64),
            _host(g.arrays.nbr_ptr)[: g.n + 1].astype(np.int64))
    shards = []
    for i in range(len(cuts) - 1):
        lo, hi = int(cuts[i]), int(cuts[i + 1])
        u, v = shard_dyads(g, lo, hi)
        halo = halo_vertices(g, lo, hi, np.unique(v))
        keep = np.union1d(np.arange(lo, hi, dtype=np.int64), halo)
        m_out = int((ptrs[0][keep + 1] - ptrs[0][keep]).sum())
        m_nbr = int((ptrs[1][keep + 1] - ptrs[1][keep]).sum())
        shards.append(ShardInfo(index=i, lo=lo, hi=hi, n_dyads=int(len(u)),
                                halo=halo, m_out=m_out, m_nbr=m_nbr))
    return GraphPartition(parts=len(shards), cuts=cuts,
                          shards=tuple(shards))
