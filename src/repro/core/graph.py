"""CSR graph representation — the TPU-native analogue of the paper's §2.5 data
structures.

The paper replaced pointer-chasing adjacency linked lists with CSR
(adjacency-array) storage on the GPU and cache-blocked lists on the CPU.  On
TPU there is no pointer chasing at all: the graph lives as flat device arrays
(CSR ``ptr``/``idx`` pairs with sorted columns), and every probe the paper did
with a list walk becomes either a vectorized binary search over the sorted
CSR rows (HBM path) or a dense tile compare (Pallas/VMEM path).

Two CSRs are kept, mirroring the paper's implementation (Fig. 4.1):
  * ``out_ptr/out_idx``   — directed out-arcs, used by ``IsEdge(u, v)``.
  * ``nbr_ptr/nbr_idx``   — open undirected neighborhoods ``N(u)``
                            (union of in- and out-arcs), used for the
                            candidate set ``S`` and ``IsNeighbour``.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


def next_pow2(x: int) -> int:
    """Smallest power of two >= x (minimum 1) — the metadata bucket and
    batch-width rounding rule shared by plan keys and the batched path."""
    return 1 << max(0, int(x) - 1).bit_length() if x > 1 else 1


class GraphArrays(NamedTuple):
    """Device-resident graph (a JAX pytree; all int32).

    ``in_ptr``/``in_idx`` hold the transpose (in-arc) CSR used by the Pallas
    tile-gather path.  They default to ``None`` (an empty pytree subtree):
    only plans that need them pay for the device-side transpose build — see
    :func:`repro.kernels.ops.build_in_csr_device` and
    ``CensusPlan.padded_arrays``.
    """

    out_ptr: jax.Array  # (n+1,)
    out_idx: jax.Array  # (m,) sorted within each row
    nbr_ptr: jax.Array  # (n+1,)
    nbr_idx: jax.Array  # (m_nbr,) sorted within each row
    nbr_deg: jax.Array  # (n,) undirected open-neighborhood sizes
    in_ptr: jax.Array | None = None  # (n+1,) transpose CSR (device-built)
    in_idx: jax.Array | None = None  # (m,)


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Host-side graph container: static metadata + device arrays."""

    n: int
    m: int  # number of directed arcs
    m_nbr: int  # total undirected adjacency entries (2 * #undirected edges)
    max_deg: int  # max undirected open-neighborhood size
    max_out_deg: int
    arrays: GraphArrays

    @property
    def n_dyads(self) -> int:
        """Number of canonical connected dyads (undirected edges)."""
        return self.m_nbr // 2


def _build_csr(n: int, rows: np.ndarray, cols: np.ndarray):
    """Sorted CSR from (row, col) pairs; rows/cols must be deduplicated."""
    order = np.lexsort((cols, rows))
    rows, cols = rows[order], cols[order]
    ptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(ptr, rows + 1, 1)
    ptr = np.cumsum(ptr)
    return ptr.astype(np.int32), cols.astype(np.int32)


def _build_host_arrays(n: int, src, dst, *, directed: bool = True):
    """The host-side (numpy) half of :func:`from_edges`: canonicalize the
    arc list and build both CSRs.  Returns ``(host GraphArrays, m, m_nbr,
    max_deg, max_out_deg)`` — shared by the device-resident and
    memory-mapped constructors so both are canonical over arc sets."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if src.size:
        keep = src != dst
        src, dst = src[keep], dst[keep]
    if not directed and src.size:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    # dedup directed arcs
    if src.size:
        key = src * np.int64(n) + dst
        _, uniq = np.unique(key, return_index=True)
        src, dst = src[uniq], dst[uniq]
    out_ptr, out_idx = _build_csr(n, src, dst)

    # undirected open neighborhoods: union of arcs in both directions
    if src.size:
        usrc = np.concatenate([src, dst])
        udst = np.concatenate([dst, src])
        ukey = usrc * np.int64(n) + udst
        _, uniq = np.unique(ukey, return_index=True)
        usrc, udst = usrc[uniq], udst[uniq]
    else:
        usrc, udst = src, dst
    nbr_ptr, nbr_idx = _build_csr(n, usrc, udst)
    deg = (nbr_ptr[1:] - nbr_ptr[:-1]).astype(np.int32)
    out_deg = out_ptr[1:] - out_ptr[:-1]
    arrays = GraphArrays(out_ptr=out_ptr, out_idx=out_idx, nbr_ptr=nbr_ptr,
                         nbr_idx=nbr_idx, nbr_deg=deg)
    return (arrays, int(src.size), int(usrc.size),
            int(deg.max()) if n and deg.size else 0,
            int(out_deg.max()) if n and out_deg.size else 0)


def from_edges(n: int, src, dst, *, directed: bool = True) -> CSRGraph:
    """Build a :class:`CSRGraph` from arc lists.

    Self-loops are dropped (the algorithm targets strict digraphs) and
    duplicate arcs are deduplicated, as in the paper's pre-processing stage.
    For ``directed=False`` every edge is materialized as a mutual dyad.
    """
    host, m, m_nbr, max_deg, max_out_deg = _build_host_arrays(
        n, src, dst, directed=directed)
    arrays = GraphArrays(
        out_ptr=jnp.asarray(host.out_ptr),
        out_idx=jnp.asarray(host.out_idx),
        nbr_ptr=jnp.asarray(host.nbr_ptr),
        nbr_idx=jnp.asarray(host.nbr_idx),
        nbr_deg=jnp.asarray(host.nbr_deg),
    )
    return CSRGraph(n=n, m=m, m_nbr=m_nbr, max_deg=max_deg,
                    max_out_deg=max_out_deg, arrays=arrays)


def from_edges_mmap(n: int, src, dst, *, directed: bool = True,
                    dir: "str | None" = None) -> CSRGraph:
    """Build a :class:`CSRGraph` whose arrays are **memory-mapped** host
    ``.npy`` files — the out-of-core constructor.

    Canonicalization is identical to :func:`from_edges` (same helper, so
    the two are bit-identical over the same arc set); the CSR arrays are
    then written to ``dir`` (a fresh temp directory when ``None``) and
    reopened read-only with ``mmap_mode="r"``, so the returned graph
    holds O(1) resident RAM per array and pages rows in on demand.  The
    partitioned engine (:mod:`repro.engine.partition`) and
    :func:`arcs_host_iter` slice these arrays one vertex range at a time,
    which is what lets a dyad stream larger than host RAM complete.
    Numpy treats a memmap as an ndarray and jax converts lazily, so an
    mmap-backed graph is accepted everywhere a device-backed one is — at
    the cost of a host→device upload on first full-array use.
    """
    import os
    import tempfile

    host, m, m_nbr, max_deg, max_out_deg = _build_host_arrays(
        n, src, dst, directed=directed)
    d = dir if dir is not None else tempfile.mkdtemp(prefix="repro-graph-")
    os.makedirs(d, exist_ok=True)

    def spill(name: str, arr: np.ndarray):
        if arr.size == 0:  # np.memmap rejects zero-length buffers
            return arr
        path = os.path.join(d, f"{name}.npy")
        mm = np.lib.format.open_memmap(path, mode="w+", dtype=arr.dtype,
                                       shape=arr.shape)
        mm[:] = arr
        mm.flush()
        del mm
        return np.load(path, mmap_mode="r")

    arrays = GraphArrays(**{f: spill(f, v) for f, v in
                            zip(("out_ptr", "out_idx", "nbr_ptr", "nbr_idx",
                                 "nbr_deg"), host[:5])})
    return CSRGraph(n=n, m=m, m_nbr=m_nbr, max_deg=max_deg,
                    max_out_deg=max_out_deg, arrays=arrays)


def arcs_host(g: CSRGraph) -> "tuple[np.ndarray, np.ndarray]":
    """Recover the directed arc list ``(src, dst)`` as host int64 arrays
    from the out-CSR — the exact inverse of :func:`from_edges` for
    deduplicated strict digraphs.  Used by graph rewrites that re-enter
    ``from_edges`` (delta application, vertex relabeling): slicing to
    ``g.n + 1`` / ``g.m`` keeps this correct on bucket-padded arrays."""
    out_ptr = np.asarray(g.arrays.out_ptr)[: g.n + 1]
    dst = np.asarray(g.arrays.out_idx)[: g.m].astype(np.int64)
    src = np.repeat(np.arange(g.n, dtype=np.int64), np.diff(out_ptr))
    return src, dst


def arcs_host_iter(g: CSRGraph, *, cuts=None, block: int = 1 << 16):
    """Stream the directed arc list shard-at-a-time: yields one
    ``(src, dst)`` int64 pair per contiguous vertex range, reading only
    that range's CSR rows per step — O(range) resident host memory on an
    mmap-backed graph (:func:`from_edges_mmap`), where :func:`arcs_host`
    would materialize the full list.  Ranges come from ``cuts`` (e.g.
    :func:`repro.core.partition.partition_cuts`, to iterate exactly the
    engine's shards) or fixed ``block``-sized strides.  Concatenating
    every yield reproduces :func:`arcs_host` exactly."""
    ptr = g.arrays.out_ptr
    ptr = (ptr if isinstance(ptr, np.ndarray)
           else np.asarray(ptr))[: g.n + 1].astype(np.int64)
    idx = g.arrays.out_idx
    if not isinstance(idx, np.ndarray):  # fetch device arrays ONCE
        idx = np.asarray(idx)
    bounds = (np.asarray(cuts, dtype=np.int64) if cuts is not None
              else np.arange(0, g.n + block, block,
                             dtype=np.int64).clip(max=g.n))
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        lo, hi = int(lo), int(hi)
        if hi <= lo:
            continue
        dst = np.asarray(idx[ptr[lo]:ptr[hi]], dtype=np.int64)
        src = np.repeat(np.arange(lo, hi, dtype=np.int64),
                        np.diff(ptr[lo:hi + 1]))
        yield src, dst


def stack_graph_arrays(arrays: "list[GraphArrays]") -> GraphArrays:
    """Stack per-graph :class:`GraphArrays` into one batched pytree.

    Every field gains a leading batch axis ``B = len(arrays)``; the inputs
    must already share identical (bucket-padded) shapes — i.e. come from
    one plan's ``padded_arrays``/``padded_arrays_host`` — which is exactly
    the same-bucket admission rule ``CensusPlan.run_batch`` enforces.
    Optional fields (the transpose CSR) stay ``None`` unless present on
    every member.  Host (numpy) members are stacked on host and shipped
    as ONE device put per field — the cheap path for fleet batching;
    device members are stacked with ``jnp.stack``.
    """
    def stk(field):
        vals = [getattr(a, field) for a in arrays]
        if any(v is None for v in vals):
            return None
        if all(isinstance(v, np.ndarray) for v in vals):
            return jnp.asarray(np.stack(vals))
        return jnp.stack(vals)

    return GraphArrays(**{f: stk(f) for f in GraphArrays._fields})


def dense_adjacency(g: CSRGraph) -> np.ndarray:
    """(n, n) boolean adjacency — for small-graph oracles only."""
    a = np.zeros((g.n, g.n), dtype=bool)
    ptr = np.asarray(g.arrays.out_ptr)
    idx = np.asarray(g.arrays.out_idx)
    for u in range(g.n):
        a[u, idx[ptr[u] : ptr[u + 1]]] = True
    return a


def load_pajek_or_edgelist(path: str) -> CSRGraph:
    """Minimal loader for Pajek ``*Vertices/*Arcs/*Edges`` or ``u v`` lines.

    Handles the paper's 0-/1-indexed distinction (§5.1.1): Pajek files are
    1-indexed, plain edge lists are taken as 0-indexed unless a header says
    otherwise.
    """
    srcs: list[int] = []
    dsts: list[int] = []
    undirected_rows: list[int] = []
    n = 0
    mode = "edges"
    pajek = False
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith(("#", "%")):
                continue
            low = line.lower()
            if low.startswith("*vertices"):
                n = int(line.split()[1])
                pajek = True
                mode = "vertices"  # skip vertex-label lines until *arcs/*edges
                continue
            if low.startswith("*arcs"):
                mode = "arcs"
                continue
            if low.startswith("*edges"):
                mode = "undirected"
                continue
            if line.startswith("*"):
                mode = "skip"
                continue
            if mode in ("skip", "vertices"):
                continue
            parts = line.split()
            if len(parts) < 2:
                continue
            u, v = int(parts[0]), int(parts[1])
            if pajek:
                u, v = u - 1, v - 1
            srcs.append(u)
            dsts.append(v)
            if mode == "undirected":
                undirected_rows.append(len(srcs) - 1)
    src = np.array(srcs, dtype=np.int64)
    dst = np.array(dsts, dtype=np.int64)
    if undirected_rows:
        extra = np.array(undirected_rows)
        src = np.concatenate([src, dst[extra]])
        dst = np.concatenate([dst, np.array(srcs, dtype=np.int64)[extra]])
    if not n:
        n = int(max(src.max(initial=-1), dst.max(initial=-1)) + 1)
    return from_edges(n, src, dst, directed=True)
