"""Triad isomorphism tables for the sub-quadratic Triad Census algorithm.

The paper (Fig. 2.5) computes a 6-bit *triad code* for an ordered vertex
triple ``(u, v, w)``::

    code =      IsEdge(u, v)
         + 2  * IsEdge(v, u)
         + 4  * IsEdge(u, w)
         + 8  * IsEdge(w, u)
         + 16 * IsEdge(v, w)
         + 32 * IsEdge(w, v)

and maps the 64 possible codes onto the 16 isomorphism classes (MAN naming:
003, 012, 102, 021D, 021U, 021C, 111D, 111U, 030T, 030C, 201, 120D, 120U,
120C, 210, 300).  Rather than hard-coding the 64-entry table we *derive* it
here by canonicalizing every 3-vertex digraph under the 6 vertex
permutations and classifying each class structurally.  ``tests/test_triads``
asserts the known class multiplicities (1,6,3,3,3,6,6,6,6,2,3,3,3,6,6,1).
"""
from __future__ import annotations

import itertools

import numpy as np

# Canonical ordering of the 16 isomorphic triad types (index 0..15 = type 1..16).
TRIAD_NAMES: tuple[str, ...] = (
    "003", "012", "102", "021D", "021U", "021C", "111D", "111U",
    "030T", "030C", "201", "120D", "120U", "120C", "210", "300",
)


def _code_to_adj(code: int) -> np.ndarray:
    """6-bit triad code -> 3x3 adjacency matrix over vertices (u,v,w)=(0,1,2)."""
    a = np.zeros((3, 3), dtype=np.int64)
    a[0, 1] = (code >> 0) & 1
    a[1, 0] = (code >> 1) & 1
    a[0, 2] = (code >> 2) & 1
    a[2, 0] = (code >> 3) & 1
    a[1, 2] = (code >> 4) & 1
    a[2, 1] = (code >> 5) & 1
    return a


def _adj_to_code(a: np.ndarray) -> int:
    return int(
        a[0, 1] + 2 * a[1, 0] + 4 * a[0, 2] + 8 * a[2, 0] + 16 * a[1, 2] + 32 * a[2, 1]
    )


def _classify(a: np.ndarray) -> str:
    """Name the isomorphism class of a 3-vertex digraph via MAN + orientation."""
    pairs = [(0, 1), (0, 2), (1, 2)]
    mut = sum(1 for i, j in pairs if a[i, j] and a[j, i])
    asym = sum(1 for i, j in pairs if a[i, j] != a[j, i])
    null = 3 - mut - asym
    man = (mut, asym, null)
    outdeg = a.sum(axis=1)
    indeg = a.sum(axis=0)
    if man == (0, 0, 3):
        return "003"
    if man == (0, 1, 2):
        return "012"
    if man == (1, 0, 2):
        return "102"
    if man == (0, 2, 1):
        # 021D: out-star (A<-B->C); 021U: in-star (A->B<-C); 021C: path.
        if outdeg.max() == 2:
            return "021D"
        if indeg.max() == 2:
            return "021U"
        return "021C"
    if man == (1, 1, 1):
        # outsider = vertex not in the mutual dyad.
        for k in range(3):
            i, j = [x for x in range(3) if x != k]
            if a[i, j] and a[j, i]:
                outsider = k
                break
        # statnet convention: 111D = A<->B<-C (outsider sends), 111U = A<->B->C.
        return "111D" if outdeg[outsider] == 1 else "111U"
    if man == (0, 3, 0):
        # 030C: directed 3-cycle (all outdeg 1); 030T: transitive.
        return "030C" if (outdeg == 1).all() else "030T"
    if man == (1, 2, 0):
        for k in range(3):
            i, j = [x for x in range(3) if x != k]
            if a[i, j] and a[j, i]:
                outsider = k
                break
        if outdeg[outsider] == 2:
            return "120D"
        if indeg[outsider] == 2:
            return "120U"
        return "120C"
    if man == (2, 0, 1):
        return "201"
    if man == (2, 1, 0):
        return "210"
    if man == (3, 0, 0):
        return "300"
    raise AssertionError(f"unreachable MAN {man}")


def _build_table() -> np.ndarray:
    perms = list(itertools.permutations(range(3)))
    table = np.zeros(64, dtype=np.int32)
    for code in range(64):
        a = _code_to_adj(code)
        # classification is permutation-invariant; classify directly.
        name = _classify(a)
        table[code] = TRIAD_NAMES.index(name)
        # sanity: all permuted forms classify identically.
        for p in perms:
            pa = a[np.ix_(p, p)]
            assert _classify(pa) == name, (code, p)
    return table


#: 64-entry map: 6-bit triad code -> isomorphic type index in [0, 16).
TRIAD_TABLE_64: np.ndarray = _build_table()

#: Expected number of labeled codes per isomorphic class (well-known constants).
CLASS_MULTIPLICITY: np.ndarray = np.bincount(TRIAD_TABLE_64, minlength=16)
