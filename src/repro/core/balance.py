"""Graph partitioning & load balancing for irregular dyad workloads.

This module reproduces the paper's Table 4.8 strategy space and then goes
beyond it.  The paper's task abstraction is the **canonical dyad**
``(u, v), u < v``; a task's cost is the size of its candidate set.  Two cost
models from the paper:

  * ``canonical_uniform``      — ``|N(u)| + |N(v)| − 2`` (v0.7; cheap, the
    ``−2`` refinement over the Cray-XMT work is Table 4.12's contribution),
  * ``canonical_nonuniform``   — exact ``|S| = |N(u) ∪ N(v) \\ {u,v}|``
    (v0.6; precise but its *sequential host pre-computation dominated
    runtime* — Table 4.9's Amdahl wall).

And three packing disciplines:

  * ``greedy_sequential``  — the paper's queue fill: walk dyads in natural
    order, open a new queue when the running weight exceeds the quota.
    Faithful baseline; produces ragged queues (padded here).
  * ``sorted_snake``       — beyond-paper: sort by weight descending, deal
    into shards boustrophedon.  Equal task *counts* per shard (static-shape
    friendly for SPMD) and near-optimal weight balance at O(D log D) host
    cost, or fully on device.
  * ``greedy_lpt``         — classic Longest-Processing-Time bin packing
    (best balance, slowest packing; upper-bounds what balancing can buy).

On TPU the non-uniform weights are computed **on device** with the same
vectorized membership machinery as the census itself, removing the paper's
pre-processing bottleneck — we quantify that in benchmarks/bench_balance.py.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from .census import canonical_dyads, make_member_fn, _gather_neighborhood
from .graph import CSRGraph

WEIGHTS = ("vertex", "dyad_uniform", "canonical_uniform", "canonical_nonuniform")
PACKING = ("greedy_sequential", "sorted_snake", "greedy_lpt")


@dataclasses.dataclass(frozen=True)
class ShardedTasks:
    """Static, per-shard dyad tasks: everything SPMD needs."""

    u: np.ndarray  # (T, L) int32
    v: np.ndarray  # (T, L) int32
    valid: np.ndarray  # (T, L) bool
    weights: np.ndarray  # (T,) float64 — modeled per-shard work
    strategy: str
    weight_model: str

    @property
    def imbalance(self) -> float:
        """max/mean modeled work — 1.0 is perfect."""
        mean = self.weights.mean()
        return float(self.weights.max() / mean) if mean > 0 else 1.0


def dyad_weights(g: CSRGraph, u: np.ndarray, v: np.ndarray, model: str,
                 batch: int = 1024) -> np.ndarray:
    """Per-task cost under the given model (paper Table 4.8)."""
    deg = np.asarray(g.arrays.nbr_deg)
    if model == "vertex":
        # per-vertex partitioning assigns all dyads of u together; weight 1.
        return np.ones(len(u), dtype=np.float64)
    if model == "dyad_uniform":
        return np.ones(len(u), dtype=np.float64)
    if model == "canonical_uniform":
        return (deg[u] + deg[v] - 2).astype(np.float64)
    if model == "canonical_nonuniform":
        return exact_s_sizes(g, u, v, batch=batch).astype(np.float64)
    raise ValueError(f"unknown weight model {model!r}")


@functools.lru_cache(maxsize=32)
def _s_batch_fn(K: int, iters: int):
    member = make_member_fn(iters)

    @jax.jit
    def s_batch(arrays, uu, vv):
        wu, mu, _ = _gather_neighborhood(arrays, uu, K)
        wv, mv, _ = _gather_neighborhood(arrays, vv, K)
        mu = mu & (wu != vv[:, None])
        mv = mv & (wv != uu[:, None])
        dup = member(arrays.nbr_ptr, arrays.nbr_idx, uu[:, None], wv)
        return mu.sum(1) + (mv & ~dup).sum(1)

    return s_batch


def exact_s_sizes(g: CSRGraph, u: np.ndarray, v: np.ndarray, batch: int = 1024,
                  device: bool = True) -> np.ndarray:
    """|S| per dyad.  ``device=True`` uses the vectorized JAX path (ours);
    ``device=False`` mimics the paper's sequential host pre-computation."""
    if not device:
        nbr_ptr = np.asarray(g.arrays.nbr_ptr)
        nbr_idx = np.asarray(g.arrays.nbr_idx)
        out = np.empty(len(u), dtype=np.int64)
        for i, (a, b) in enumerate(zip(u, v)):
            na = nbr_idx[nbr_ptr[a]: nbr_ptr[a + 1]]
            nb = nbr_idx[nbr_ptr[b]: nbr_ptr[b + 1]]
            s = np.union1d(na, nb)
            out[i] = len(s) - np.isin([a, b], s).sum()
        return out

    K = max(1, g.max_deg)
    iters = max(1, math.ceil(math.log2(g.max_deg + 1))) + 1
    s_batch = _s_batch_fn(K, iters)

    d = len(u)
    pad = (-d) % batch
    uu = np.concatenate([u, np.zeros(pad, u.dtype)]).astype(np.int32)
    vv = np.concatenate([v, np.ones(pad, v.dtype)]).astype(np.int32)
    outs = []
    for i in range(0, len(uu), batch):
        outs.append(np.asarray(s_batch(g.arrays, jnp.asarray(uu[i:i + batch]),
                                       jnp.asarray(vv[i:i + batch]))))
    return np.concatenate(outs)[:d].astype(np.int64)


def chunk_bounds_by_cost(weights: np.ndarray, capacity: int, *,
                         target: float | None = None) -> np.ndarray:
    """Cost-model-driven chunk boundaries over a task stream.

    Splits ``[0, len(weights))`` into contiguous chunks of roughly equal
    *predicted* work — the streaming analogue of the paper's balanced
    task queues: where a fixed ``chunk_size`` gives heavy-degree regions
    of the dyad stream heavier chunks, equal-cost splitting gives them
    **smaller** ones, so a work-queue scheduler
    (:class:`repro.engine.executor.Executor`) never hands one device a
    chunk that dominates the run.

    ``capacity`` caps every chunk's *length* (the compiled chunk unit's
    static shape); ``target`` is the per-chunk cost quota, defaulting to
    ``total / ceil(D / capacity)`` so the chunk count stays comparable
    to the fixed-size schedule.  Returns an int64 boundary array ``b``
    with ``b[0] == 0``, ``b[-1] == D`` and every span in
    ``(0, capacity]``; a single task heavier than ``target`` gets a
    chunk of its own.
    """
    D = len(weights)
    if D == 0:
        return np.zeros(1, dtype=np.int64)
    if capacity < 1:
        raise ValueError(f"capacity must be >= 1, got {capacity}")
    cum = np.concatenate([[0.0], np.cumsum(weights, dtype=np.float64)])
    if target is None:
        target = cum[-1] / max(1, -(-D // capacity))
    target = max(float(target), 1e-12)
    bounds = [0]
    while bounds[-1] < D:
        s = bounds[-1]
        e = int(np.searchsorted(cum, cum[s] + target, side="right")) - 1
        bounds.append(min(max(e, s + 1), s + capacity, D))
    return np.asarray(bounds, dtype=np.int64)


def _pad_shards(shards: list[np.ndarray], u, v):
    L = max((len(s) for s in shards), default=1) or 1
    T = len(shards)
    su = np.zeros((T, L), np.int32)
    sv = np.ones((T, L), np.int32)
    mask = np.zeros((T, L), bool)
    for t, s in enumerate(shards):
        su[t, : len(s)] = u[s]
        sv[t, : len(s)] = v[s]
        mask[t, : len(s)] = True
    return su, sv, mask


def pack_tasks(g: CSRGraph, n_shards: int, *, weight_model: str = "canonical_uniform",
               strategy: str = "sorted_snake", pad_multiple: int = 1) -> ShardedTasks:
    """Partition all canonical dyads into ``n_shards`` balanced static shards."""
    u, v = canonical_dyads(g)
    w = dyad_weights(g, u, v, weight_model)
    D = len(u)
    idx = np.arange(D)

    if strategy == "greedy_sequential":
        # Paper Fig 4.4/4.5: fill queues in natural order until quota reached.
        quota = w.sum() / n_shards
        shards: list[list[int]] = [[] for _ in range(n_shards)]
        t, acc = 0, 0.0
        for i in idx:
            shards[t].append(i)
            acc += w[i]
            if acc > quota and t + 1 < n_shards:
                t, acc = t + 1, 0.0
        shard_idx = [np.array(s, dtype=np.int64) for s in shards]
    elif strategy == "sorted_snake":
        order = np.argsort(-w, kind="stable")
        rounds = math.ceil(D / n_shards)
        pos = np.arange(D)
        r, c = pos // n_shards, pos % n_shards
        col = np.where(r % 2 == 0, c, n_shards - 1 - c)
        shard_of = np.empty(D, dtype=np.int64)
        shard_of[order] = col
        shard_idx = [idx[shard_of == t] for t in range(n_shards)]
    elif strategy == "greedy_lpt":
        import heapq

        order = np.argsort(-w, kind="stable")
        heap = [(0.0, t) for t in range(n_shards)]
        heapq.heapify(heap)
        shards = [[] for _ in range(n_shards)]
        for i in order:
            load, t = heapq.heappop(heap)
            shards[t].append(i)
            heapq.heappush(heap, (load + w[i], t))
        shard_idx = [np.array(s, dtype=np.int64) for s in shards]
    else:
        raise ValueError(f"unknown strategy {strategy!r}")

    su, sv, mask = _pad_shards(shard_idx, u, v)
    if pad_multiple > 1:
        L = su.shape[1]
        pad = (-L) % pad_multiple
        if pad:
            su = np.pad(su, ((0, 0), (0, pad)))
            sv = np.pad(sv, ((0, 0), (0, pad)), constant_values=1)
            mask = np.pad(mask, ((0, 0), (0, pad)))
    loads = np.array([w[s].sum() for s in shard_idx])
    return ShardedTasks(u=su, v=sv, valid=mask, weights=loads,
                        strategy=strategy, weight_model=weight_model)
