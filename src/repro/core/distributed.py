"""Multi-pod distributed Triad Census via ``jax.shard_map``.

Maps the paper's parallelization (one task queue per hardware thread,
decoupled per-thread census arrays, single final merge) onto an SPMD mesh:

  * every mesh device receives one **static task shard** from
    :mod:`repro.core.balance` (the task-queue analogue),
  * the graph CSR is replicated (the paper's shared-memory model),
  * each device accumulates a private 16-bin census (the decoupled local
    census array) and a single ``psum`` over all mesh axes performs the
    paper's end-of-run merge — the only communication in the whole job.

The collective schedule is therefore exactly one 64-byte all-reduce, which
is why the census is compute-bound at any pod size (see EXPERIMENTS.md).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from . import balance
from .census import CensusResult, make_census_batch_fn
from .graph import CSRGraph


def make_distributed_census_fn(g: CSRGraph, mesh: jax.sharding.Mesh, *,
                               batch: int = 256, K: int | None = None,
                               acc_dtype=jnp.int32):
    """Build a shard_map'd census over every device of ``mesh``.

    The returned jitted fn takes ``(graph_arrays, n, tasks_u, tasks_v,
    valid)`` with task arrays shaped ``(n_devices, L)`` (L a multiple of
    ``batch``) and returns the merged ``(16,)`` connected/dyadic census.
    """
    K = K or max(1, g.max_deg)
    member_iters = max(1, math.ceil(math.log2(max(g.max_deg, g.max_out_deg, 1) + 1))) + 1
    batch_fn = make_census_batch_fn(K, member_iters, acc_dtype)
    axes = tuple(mesh.axis_names)

    def device_census(arrays, n, u, v, valid):
        # u, v, valid: (1, L) local block — one task shard per device.
        u, v, valid = u[0], v[0], valid[0]
        steps = u.shape[0] // batch

        def step(carry, xs):
            uu, vv, va = xs
            return carry + batch_fn(arrays, n, uu, vv, va), None

        init = jax.lax.pvary(jnp.zeros((16,), acc_dtype), axes)
        counts, _ = jax.lax.scan(
            step, init,
            (u.reshape(steps, batch), v.reshape(steps, batch),
             valid.reshape(steps, batch)),
        )
        # the paper's final merge: one tree-reduction over all workers.
        for ax in axes:
            counts = jax.lax.psum(counts, ax)
        return counts

    shmap = jax.shard_map(
        device_census,
        mesh=mesh,
        in_specs=(P(), P(), P(axes), P(axes), P(axes)),
        out_specs=P(),
    )
    return jax.jit(shmap)


def distributed_triad_census(
    g: CSRGraph,
    mesh: jax.sharding.Mesh,
    *,
    weight_model: str = "canonical_uniform",
    strategy: str = "sorted_snake",
    batch: int = 256,
    K: int | None = None,
) -> tuple[CensusResult, balance.ShardedTasks]:
    """Partition, balance, and run the census over all devices of ``mesh``."""
    n_dev = math.prod(mesh.devices.shape)
    tasks = balance.pack_tasks(g, n_dev, weight_model=weight_model,
                               strategy=strategy, pad_multiple=batch)
    fn = make_distributed_census_fn(g, mesh, batch=batch, K=K)
    counts = fn(g.arrays, jnp.int32(g.n), jnp.asarray(tasks.u),
                jnp.asarray(tasks.v), jnp.asarray(tasks.valid))
    counts = np.asarray(counts, dtype=np.int64)
    total = g.n * (g.n - 1) * (g.n - 2) // 6
    counts[0] = total - int(counts.sum())
    return CensusResult(counts=counts), tasks
