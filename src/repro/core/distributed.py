"""Multi-pod distributed Triad Census via ``shard_map`` (see repro.compat).

.. deprecated:: prefer ``repro.engine.compile_census`` with
   ``CensusConfig(backend="distributed")`` — it adds the plan cache and
   chunked streaming on top of the same shard_map schedule built here.

Maps the paper's parallelization (one task queue per hardware thread,
decoupled per-thread census arrays, single final merge) onto an SPMD mesh:

  * every mesh device receives one **static task shard** from
    :mod:`repro.core.balance` (the task-queue analogue),
  * the graph CSR is replicated (the paper's shared-memory model),
  * each device accumulates a private 16-bin census (the decoupled local
    census array) and a single ``psum`` over all mesh axes performs the
    paper's end-of-run merge — the only communication in the whole job.

The collective schedule is therefore exactly one 64-byte all-reduce, which
is why the census is compute-bound at any pod size (see EXPERIMENTS.md).
"""
from __future__ import annotations

import math
import warnings

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import compat
from .census import make_census_batch_fn
from .graph import CSRGraph


def make_census_fn_for_mesh(mesh: jax.sharding.Mesh, *, K: int | None = None,
                            member_iters: int | None = None, batch: int = 256,
                            acc_dtype=jnp.int32, on_trace=None,
                            batch_fn=None, n_bins: int = 16):
    """Build a shard_map'd per-batch kernel sweep over every device of
    ``mesh``.

    The single definition of the SPMD schedule — the legacy
    ``make_distributed_census_fn`` and the engine's distributed backend
    both call this.  The returned jitted fn takes ``(graph_arrays, n,
    tasks_u, tasks_v, valid)`` with task arrays shaped ``(n_devices, L)``
    (L a multiple of ``batch``) and returns the merged ``(n_bins,)``
    partial counts.  By default the kernel is the triad census built from
    ``K`` / ``member_iters``; the engine's fused multi-analytic path
    passes its own ``batch_fn`` (any ``(arrays, n, u, v, valid) ->
    (n_bins,)`` additive kernel — see :mod:`repro.engine.ops`) plus the
    matching ``n_bins``.  ``on_trace`` (if set) is invoked as a
    trace-time side effect — the engine uses it to count retraces.
    """
    if batch_fn is None:
        batch_fn = make_census_batch_fn(K, member_iters, acc_dtype)
    axes = tuple(mesh.axis_names)

    def device_census(arrays, n, u, v, valid):
        if on_trace is not None:
            on_trace()
        # u, v, valid: (1, L) local block — one task shard per device.
        u, v, valid = u[0], v[0], valid[0]
        steps = u.shape[0] // batch

        def step(carry, xs):
            uu, vv, va = xs
            return carry + batch_fn(arrays, n, uu, vv, va), None

        init = compat.pvary(jnp.zeros((n_bins,), acc_dtype), axes)
        counts, _ = jax.lax.scan(
            step, init,
            (u.reshape(steps, batch), v.reshape(steps, batch),
             valid.reshape(steps, batch)),
        )
        # the paper's final merge: one tree-reduction over all workers.
        for ax in axes:
            counts = jax.lax.psum(counts, ax)
        return counts

    shmap = compat.shard_map(
        device_census,
        mesh=mesh,
        in_specs=(P(), P(), P(axes), P(axes), P(axes)),
        out_specs=P(),
    )
    return jax.jit(shmap)


def make_distributed_census_fn(g: CSRGraph, mesh: jax.sharding.Mesh, *,
                               batch: int = 256, K: int | None = None,
                               acc_dtype=jnp.int32):
    """Legacy builder: derives K/member_iters from ``g`` (see
    :func:`make_census_fn_for_mesh` for the schedule itself)."""
    K = K or max(1, g.max_deg)
    member_iters = max(1, math.ceil(math.log2(max(g.max_deg, g.max_out_deg, 1) + 1))) + 1
    return make_census_fn_for_mesh(mesh, K=K, member_iters=member_iters,
                                   batch=batch, acc_dtype=acc_dtype)


def distributed_triad_census(
    g: CSRGraph,
    mesh: jax.sharding.Mesh,
    *,
    weight_model: str = "canonical_uniform",
    strategy: str = "sorted_snake",
    batch: int = 256,
    K: int | None = None,
):
    """Partition, balance, and run the census over all devices of ``mesh``.

    .. deprecated:: thin shim over ``repro.engine`` (plan cache + chunked
       streaming included).  Returns ``(CensusResult, task_stats)`` where
       ``task_stats`` is the lightweight per-shard load summary (it has
       ``.imbalance`` / ``.weights`` like the old ``ShardedTasks`` but not
       the task arrays; call :func:`repro.core.balance.pack_tasks` if you
       need those).
    """
    from ..engine import CensusConfig, compile_census

    warnings.warn(
        "repro.core.distributed.distributed_triad_census is deprecated; use "
        "repro.engine.compile_census with CensusConfig(backend='distributed')",
        DeprecationWarning, stacklevel=2)
    cfg = CensusConfig(backend="distributed", batch=batch, k=K,
                       strategy=strategy, weight_model=weight_model)
    plan = compile_census(g, cfg, mesh=mesh)
    res = plan.run(g)
    return res, plan.last_task_stats
