"""Graph mutations and their exact blast radius (the delta-census core).

The engine's batch pass answers "what is the census of THIS graph"; the
serving north-star is a stream of edge insertions/deletions against a
graph whose census is already known (Chin et al., arXiv:1209.6308 —
triadic analysis of *evolving* social graphs).  Because every per-dyad
kernel contribution is a pure function of the dyad's own arcs and the
arcs between ``{u, v}`` and ``N(u) ∪ N(v)`` (the paper's closed
neighborhoods), an edge-only mutation can change the contribution of a
canonical dyad ``(u, v)`` **only if u or v is an endpoint of a touched
edge** — probes against a third vertex ``w`` test membership of ``u``/
``v`` in w's rows, and any arc between ``w`` and the dyad that changed
would put ``u`` or ``v`` in the touched set by definition.  That makes
the affected set exact, not heuristic, and enumerable straight from the
undirected CSR rows of the touched vertices.

This module is pure host/NumPy: :class:`GraphDelta` (validated, deduped
edge lists), :func:`affected_dyads` (the exact canonical-dyad blast
radius on one graph), and :func:`apply_delta_csr` (the mutated
:class:`~repro.core.graph.CSRGraph`).  The device-side correction pass
lives in :mod:`repro.engine.delta`.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .graph import CSRGraph, arcs_host, from_edges

__all__ = ["GraphDelta", "affected_dyads", "apply_delta_csr"]


def _normalize_edges(edges, what: str) -> np.ndarray:
    """Coerce an edge spec into a deduplicated ``(k, 2)`` int64 array.

    Accepts ``None``, an iterable of ``(u, v)`` pairs, or an array-like
    of shape ``(k, 2)``.  Self-loops are dropped (the census is defined
    on strict digraphs — ``from_edges`` would drop them anyway) and
    duplicate arcs collapse to one; negative endpoints are rejected here,
    upper bounds against a concrete graph in :meth:`GraphDelta.validate_for`.
    """
    if edges is None:
        return np.zeros((0, 2), dtype=np.int64)
    a = np.asarray(list(edges) if not isinstance(edges, np.ndarray) else edges,
                   dtype=np.int64)
    if a.size == 0:
        return np.zeros((0, 2), dtype=np.int64)
    if a.ndim != 2 or a.shape[1] != 2:
        raise ValueError(f"{what} must be (k, 2) arc pairs, got shape "
                         f"{a.shape}")
    if (a < 0).any():
        raise ValueError(f"{what} endpoints must be >= 0")
    a = a[a[:, 0] != a[:, 1]]  # strict digraph: self-loops are inert
    if len(a):
        a = np.unique(a, axis=0)
    return a


@dataclasses.dataclass(frozen=True)
class GraphDelta:
    """One batch of edge mutations against a fixed vertex set.

    ``edges_removed`` are applied first, then ``edges_added`` — an arc in
    both lists is present afterwards.  Removing an absent arc or adding a
    present one is a no-op (``from_edges`` deduplicates), so deltas are
    safe to replay.  Both lists are normalized at construction: ``(k, 2)``
    int64, self-loops dropped, duplicates collapsed, negatives rejected;
    endpoint upper bounds are checked against a concrete graph by
    :meth:`validate_for` (the vertex set itself never changes — grow the
    graph by rebuilding it with :func:`repro.core.graph.from_edges`).
    """

    edges_added: np.ndarray = None
    edges_removed: np.ndarray = None

    def __post_init__(self):
        object.__setattr__(self, "edges_added",
                           _normalize_edges(self.edges_added, "edges_added"))
        object.__setattr__(self, "edges_removed",
                           _normalize_edges(self.edges_removed,
                                            "edges_removed"))

    @property
    def size(self) -> int:
        """Total arcs named by the delta (after normalization)."""
        return len(self.edges_added) + len(self.edges_removed)

    @property
    def is_empty(self) -> bool:
        """True when the delta cannot change any graph it is valid for."""
        return self.size == 0

    @property
    def touched(self) -> np.ndarray:
        """Sorted unique vertex ids appearing as any named arc's endpoint —
        the seed set of the affected-dyad closure."""
        if self.is_empty:
            return np.zeros(0, dtype=np.int64)
        return np.unique(np.concatenate([self.edges_added.ravel(),
                                         self.edges_removed.ravel()]))

    def permuted(self, perm) -> "GraphDelta":
        """The same mutation expressed in relabeled vertex ids: every
        endpoint ``x`` becomes ``perm[x]``.  This is the boundary
        translation the engine's ``reorder=`` path uses — callers express
        deltas in original ids, and because
        :func:`~repro.core.graph.from_edges` is canonical over arc sets,
        applying the permuted delta to the permuted graph yields exactly
        the permutation of the mutated graph."""
        p = np.asarray(perm, dtype=np.int64)
        return GraphDelta(
            edges_added=p[self.edges_added] if len(self.edges_added)
            else self.edges_added,
            edges_removed=p[self.edges_removed] if len(self.edges_removed)
            else self.edges_removed,
        )

    def validate_for(self, g: CSRGraph) -> None:
        """Raise ``ValueError`` unless every endpoint is a vertex of ``g``."""
        if self.size and int(self.touched[-1]) >= g.n:
            raise ValueError(
                f"delta touches vertex {int(self.touched[-1])} but the graph "
                f"has n={g.n} vertices (the vertex set is fixed; rebuild via "
                "from_edges to grow it)")


def affected_dyads(g: CSRGraph, delta: GraphDelta
                   ) -> "tuple[np.ndarray, np.ndarray]":
    """Canonical dyads of ``g`` whose kernel contribution the delta can
    change: every ``(u, v), u < v`` of ``g`` with an endpoint in
    ``delta.touched`` (see the module docstring for why this set is
    exact).  Returned as sorted ``(u, v)`` int32 arrays — order is
    irrelevant to correctness (integer accumulation) but determinism
    keeps chunk schedules reproducible.

    Dyads *created or destroyed* by the delta are handled by evaluating
    this on the old and the new graph separately
    (:func:`repro.engine.delta.delta_correction` does both): a created
    dyad appears only in the new graph's set, a destroyed one only in the
    old's, and both are incident to touched vertices by construction.
    """
    delta.validate_for(g)
    t = delta.touched
    if not len(t) or g.n_dyads == 0:
        return (np.zeros(0, dtype=np.int32),) * 2
    nbr_ptr = np.asarray(g.arrays.nbr_ptr)
    nbr_idx = np.asarray(g.arrays.nbr_idx)
    starts, ends = nbr_ptr[t], nbr_ptr[t + 1]
    deg = ends - starts
    total = int(deg.sum())
    if total == 0:
        return (np.zeros(0, dtype=np.int32),) * 2
    # vectorized multi-row CSR gather: position r of the concatenation maps
    # to starts[row(r)] + (r - cum_deg[row(r)]).
    rows = np.repeat(t, deg)
    offs = np.arange(total) - np.repeat(np.cumsum(deg) - deg, deg)
    cols = nbr_idx[np.repeat(starts, deg) + offs]
    u = np.minimum(rows, cols)
    v = np.maximum(rows, cols)
    key = np.unique(u * np.int64(g.n) + v)  # canonicalize + dedup, sorted
    return ((key // g.n).astype(np.int32), (key % g.n).astype(np.int32))


def apply_delta_csr(g: CSRGraph, delta: GraphDelta) -> CSRGraph:
    """The mutated graph: ``g``'s arcs minus ``edges_removed`` plus
    ``edges_added``, rebuilt through the same
    :func:`~repro.core.graph.from_edges` pipeline every graph enters by
    (sorted CSR rows, deduplication), so a delta-built graph is
    bit-identical to one built from the mutated edge list directly.
    The vertex count is preserved."""
    delta.validate_for(g)
    src, dst = arcs_host(g)
    if len(delta.edges_removed):
        key = src * np.int64(g.n) + dst
        rem = (delta.edges_removed[:, 0] * np.int64(g.n)
               + delta.edges_removed[:, 1])
        keep = ~np.isin(key, rem)
        src, dst = src[keep], dst[keep]
    if len(delta.edges_added):
        src = np.concatenate([src, delta.edges_added[:, 0]])
        dst = np.concatenate([dst, delta.edges_added[:, 1]])
    return from_edges(g.n, src, dst, directed=True)
