"""Vectorized sub-quadratic Triad Census (Batagelj–Mrvar, paper Fig. 2.4/2.5).

The public entry point now lives in :mod:`repro.engine`
(``compile_census(graph, CensusConfig(...)).run(graph)``); ``triad_census``
here is a deprecated thin shim over it.  This module keeps the algorithm
building blocks the engine composes: the membership probe, the per-batch
census kernel, dyad enumeration/padding, and the brute-force oracle.

TPU-native reformulation of the paper's algorithm:

  * The per-dyad linked-list walks become **batched dense candidate tiles**:
    a batch of ``B`` canonical dyads gathers its two neighborhoods as
    ``(B, K)`` tiles straight from the CSR column array (``K`` = max degree,
    optionally per-bucket — see :mod:`repro.core.balance`).
  * ``IsEdge``/``IsNeighbour`` become **fixed-trip vectorized binary
    searches** over the sorted CSR rows (the paper's §4.2.4 v0.5 "faster
    searching" — binary search beat linear search there too).
  * The paper's v0.4 optimization (pre-computed dyad code, 6→4 edge probes
    in ``TriadCode``) carries over verbatim: the dyad code is computed once
    per dyad and broadcast over its ``w`` candidates.
  * The paper's "decoupled per-thread census arrays" become per-batch
    partial histograms combined by a single reduction at the end — no
    scatter contention, no atomics (TPU has none anyway).

A dedup insight the vectorization exposes: the paper's canonicality test
(line 16, Fig. 2.4) calls ``IsNeighbour(u, w)`` — but for candidates drawn
from ``N(u)`` that test is *always true* and for candidates drawn from
``N(v)`` it is exactly the union-dedup membership test.  So one membership
probe per ``N(v)`` candidate serves both the set union and the canonicality
test; candidates from ``N(u)`` need none.
"""
from __future__ import annotations

import functools
import math
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import CSRGraph, GraphArrays, dense_adjacency
from .triad_table import TRIAD_TABLE_64


class CensusResult(NamedTuple):
    """A finished triad census: ``counts[i]`` is the number of triads of
    type ``i + 1`` in MAN notation ("003" .. "300", see
    :data:`repro.core.triad_table.TRIAD_NAMES`), int64, including the
    type-003 closed form.  ``total`` always equals C(n, 3)."""

    counts: np.ndarray  # (16,) int64 — types 1..16 ("003".."300")

    @property
    def total(self) -> int:
        return int(self.counts.sum())


def make_member_fn(n_iters: int):
    """Vectorized sorted-CSR membership probe (binary search, fixed trips).

    ``member(ptr, idx, rows, queries) -> bool array`` broadcasting ``rows``
    against ``queries``; ``n_iters >= ceil(log2(max_row_len + 1))``.
    """

    def member(ptr: jax.Array, idx: jax.Array, rows: jax.Array, queries: jax.Array):
        rows_b = jnp.broadcast_to(rows, jnp.broadcast_shapes(rows.shape, queries.shape))
        q = jnp.broadcast_to(queries, rows_b.shape)
        lo = ptr[rows_b]
        hi = ptr[rows_b + 1]
        last = idx.shape[0] - 1

        def body(_, state):
            lo, hi = state
            active = lo < hi
            mid = (lo + hi) >> 1
            v = idx[jnp.clip(mid, 0, last)]
            go_right = v < q
            new_lo = jnp.where(active & go_right, mid + 1, lo)
            new_hi = jnp.where(active & ~go_right, mid, hi)
            return new_lo, new_hi

        lo, hi = jax.lax.fori_loop(0, n_iters, body, (lo, hi))
        found = (lo < ptr[rows_b + 1]) & (idx[jnp.clip(lo, 0, last)] == q)
        return found

    return member


def _gather_neighborhood(g: GraphArrays, u: jax.Array, K: int):
    """Gather N(u) for a batch as a dense (B, K) tile + validity mask."""
    start = g.nbr_ptr[u]  # (B,)
    deg = g.nbr_deg[u]
    j = jnp.arange(K, dtype=jnp.int32)
    pos = start[:, None] + j[None, :]
    last = g.nbr_idx.shape[0] - 1
    w = g.nbr_idx[jnp.clip(pos, 0, last)]
    mask = j[None, :] < deg[:, None]
    return w, mask, deg


def make_census_batch_fn(K: int, member_iters: int, acc_dtype=jnp.int32,
                         six_probe: bool = False):
    """Build the per-batch census kernel (pure jnp; also the Pallas oracle).

    Returns ``f(graph_arrays, n, u, v, valid) -> (16,) partial counts`` for a
    batch of canonical dyads ``(u, v), u < v``.  Null triads (type 003) are
    *not* counted here — they come from the closed form at the end (paper
    line 29).

    ``six_probe=True`` disables the paper's v0.4 optimization: the dyad
    code is re-derived per candidate (6 membership probes instead of 4) —
    the pre-optimization baseline for benchmarks/run.py.
    """
    member = make_member_fn(member_iters)
    table = jnp.asarray(TRIAD_TABLE_64, dtype=jnp.int32)

    def batch_census(g: GraphArrays, n: jax.Array, u: jax.Array, v: jax.Array, valid: jax.Array):
        B = u.shape[0]
        wu, mu, deg_u = _gather_neighborhood(g, u, K)  # (B, K)
        wv, mv, deg_v = _gather_neighborhood(g, v, K)
        mu = mu & valid[:, None]
        mv = mv & valid[:, None]
        # S = N(u) ∪ N(v) \ {u, v}; N(u) never contains u, N(v) never v.
        mu = mu & (wu != v[:, None])
        mv = mv & (wv != u[:, None])
        # union dedup: drop N(v) candidates already present in N(u).
        in_nu = member(g.nbr_ptr, g.nbr_idx, u[:, None], wv)
        mv_only = mv & ~in_nu
        s_size = mu.sum(1, dtype=acc_dtype) + mv_only.sum(1, dtype=acc_dtype)  # (B,)

        # --- dyadic triads (paper lines 9-14) -------------------------------
        e_uv = member(g.out_ptr, g.out_idx, u, v)
        e_vu = member(g.out_ptr, g.out_idx, v, u)
        dyad_code = e_uv.astype(jnp.int32) + 2 * e_vu.astype(jnp.int32)  # in {1,2,3}
        # type index (0-based): mutual -> 2 ("102"), else 1 ("012")
        dyad_type = jnp.where(dyad_code == 3, 2, 1)
        dyadic = jnp.where(valid, n.astype(acc_dtype) - s_size - 2, 0)

        # --- connected triads (paper lines 15-20) ---------------------------
        # canonicality: count w iff  v<w  or  (w<v and u<w and not IsNbr(u,w)).
        canon_u = mu & (wu > v[:, None])  # w ∈ N(u) ⇒ IsNbr(u,w) true
        canon_v = mv_only & ((wv > v[:, None]) | ((wv > u[:, None]) & (wv < v[:, None])))

        def codes_for(w, canon):
            if six_probe:
                # pre-v0.4 baseline: re-derive the dyad code per candidate
                c = (member(g.out_ptr, g.out_idx, u[:, None],
                            jnp.broadcast_to(v[:, None], w.shape)).astype(jnp.int32)
                     + 2 * member(g.out_ptr, g.out_idx, v[:, None],
                                  jnp.broadcast_to(u[:, None], w.shape)).astype(jnp.int32))
            else:
                # paper v0.4: dyad code precomputed, 4 IsEdge probes remain.
                c = dyad_code[:, None]
            c = c + 4 * member(g.out_ptr, g.out_idx, u[:, None], w).astype(jnp.int32)
            c = c + 8 * member(g.out_ptr, g.out_idx, w, u[:, None]).astype(jnp.int32)
            c = c + 16 * member(g.out_ptr, g.out_idx, v[:, None], w).astype(jnp.int32)
            c = c + 32 * member(g.out_ptr, g.out_idx, w, v[:, None]).astype(jnp.int32)
            t = table[c]
            return jnp.where(canon, t, 0), canon

        t_u, m_u = codes_for(wu, canon_u)
        t_v, m_v = codes_for(wv, canon_v)

        counts = jnp.zeros((16,), dtype=acc_dtype)
        counts = counts.at[t_u.reshape(-1)].add(m_u.reshape(-1).astype(acc_dtype))
        counts = counts.at[t_v.reshape(-1)].add(m_v.reshape(-1).astype(acc_dtype))
        # masked-out lanes accumulated into bin 0 ("003"); zero it — null
        # triads come from the closed form.
        counts = counts.at[0].set(0)
        counts = counts + jnp.zeros((16,), acc_dtype).at[dyad_type].add(dyadic)
        return counts

    return batch_census


def pad_dyads(u: np.ndarray, v: np.ndarray, batch: int):
    """Pad dyad lists to a multiple of ``batch``; returns (u, v, valid)."""
    d = len(u)
    pad = (-d) % batch
    u = np.concatenate([u, np.zeros(pad, u.dtype)])
    v = np.concatenate([v, np.ones(pad, v.dtype)])  # (0,1) keeps u<v invariant
    valid = np.concatenate([np.ones(d, bool), np.zeros(pad, bool)])
    return u.astype(np.int32), v.astype(np.int32), valid


def canonical_dyads(g: CSRGraph) -> tuple[np.ndarray, np.ndarray]:
    """All canonical connected dyads (u, v) with u < v (host-side numpy)."""
    nbr_ptr = np.asarray(g.arrays.nbr_ptr)
    nbr_idx = np.asarray(g.arrays.nbr_idx)
    deg = np.diff(nbr_ptr)
    rows = np.repeat(np.arange(g.n, dtype=np.int32), deg)
    cols = nbr_idx
    keep = cols > rows
    return rows[keep], cols[keep]


@functools.partial(jax.jit, static_argnames=("out_size",))
def enumerate_dyads_device(nbr_ptr: jax.Array, nbr_idx: jax.Array,
                           m_nbr: jax.Array, *, out_size: int):
    """Device-side :func:`canonical_dyads`: jitted, fixed-shape.

    Inputs are the bucket-padded undirected CSR (see
    ``CensusPlan.padded_arrays``) plus the true entry count ``m_nbr``
    (traced, so same-bucket graphs share one trace).  Returns ``(u, v)``
    int32 arrays of static length ``out_size`` holding the canonical dyads
    in CSR row-major order — identical order to the host enumeration —
    padded past ``m_nbr // 2`` with the inert ``(0, 1)`` dyad.

    The CSR row of every entry is recovered with one vectorized
    ``searchsorted`` over the ptr array, and the ``col > row`` filter is
    compacted by gathering rank ``r``'s source position out of the running
    keep-count (a second searchsorted — all gathers, no XLA:CPU scatter,
    no data-dependent shape, no host round trip).
    """
    M = nbr_idx.shape[0]
    pos = jnp.arange(M, dtype=jnp.int32)
    rows = (jnp.searchsorted(nbr_ptr, pos, side="right") - 1).astype(jnp.int32)
    keep = (pos < m_nbr) & (nbr_idx > rows)
    csum = jnp.cumsum(keep, dtype=jnp.int32)
    rank = jnp.arange(out_size, dtype=jnp.int32)
    src = jnp.clip(jnp.searchsorted(csum, rank + 1, side="left"), 0, M - 1)
    live = rank < (m_nbr // 2)
    return (jnp.where(live, rows[src], 0),
            jnp.where(live, nbr_idx[src], 1))


@functools.partial(jax.jit, static_argnames=("ks",))
def sort_dyads_by_bucket(nbr_deg: jax.Array, out_ptr: jax.Array,
                         u: jax.Array, v: jax.Array, n_dyads: jax.Array, *,
                         ks: tuple):
    """Device-side degree-bucket assignment + load-balancing sort.

    For each dyad the tile-width *need* is ``max(deg(u), deg(v),
    out_deg(u), out_deg(v))``; its bucket is the smallest ``ks[i] >= need``.
    Dyads are stable-sorted by (bucket, need) — two chained stable argsorts,
    which avoids composing a single wide sort key that could overflow int32
    — so tile rows inside a chunk are degree-ordered: gathers hit
    neighboring CSR segments (coalescing) and blocks have uniform work
    (load balance).  Padding dyads sort past every real bucket.

    Returns ``(u_sorted, v_sorted, bucket_counts)`` with ``bucket_counts``
    of static length ``len(ks)`` — the only value the host needs to drive
    the per-bucket chunk loop (one scalar-array transfer per run).
    """
    out_deg = out_ptr[1:] - out_ptr[:-1]
    need = jnp.maximum(jnp.maximum(nbr_deg[u], nbr_deg[v]),
                       jnp.maximum(out_deg[u], out_deg[v]))
    ks_arr = jnp.asarray(ks, dtype=jnp.int32)
    b = jnp.sum(need[:, None] > ks_arr[None, :], axis=1).astype(jnp.int32)
    live = jnp.arange(u.shape[0], dtype=jnp.int32) < n_dyads
    b = jnp.where(live, b, len(ks))
    by_need = jnp.argsort(need)
    order = by_need[jnp.argsort(b[by_need])]  # stable: bucket, then need
    counts = jnp.zeros(len(ks) + 1, jnp.int32).at[b].add(1)
    return u[order], v[order], counts[: len(ks)]


def host_bucket_schedule(g: CSRGraph, ks: tuple, *,
                         with_needs: bool = True
                         ) -> "tuple[np.ndarray, np.ndarray | None]":
    """Host-side mirror of :func:`sort_dyads_by_bucket`'s control outputs.

    Returns ``(bucket_counts, need_sorted)``: the per-bucket dyad counts
    (identical, by construction, to the histogram the device sort
    computes — same ``need`` formula over the same live dyads) and each
    dyad's tile-width need in the device stream's (bucket, need) sort
    order.  Both are derived from the degree arrays the host already
    owns, so the pallas driver can lay out its per-bucket chunk loop —
    and the executor its cost-model chunk boundaries — **without the
    device→host control fetch** the engine used to pay (the fetch also
    serialized the pipeline: no chunk could be scheduled until the
    device sort finished).

    ``with_needs=False`` skips the O(D log D) sort and returns ``None``
    for ``need_sorted`` — the static schedule only consumes the counts.

    Operates on whatever graph it is handed: under the engine's
    ``reorder=`` preprocessing the plan passes the RELABELED graph, so
    the schedule is computed over reordered degrees and keyed (in the
    plan's per-graph memo) on the relabeled graph's identity — degree
    multisets are permutation-invariant, so bucket counts match the
    unreordered run's exactly while the per-dyad sort order follows the
    relabeled stream the device actually executes.
    """
    u, v = canonical_dyads(g)
    deg = np.asarray(g.arrays.nbr_deg)
    out_deg = np.diff(np.asarray(g.arrays.out_ptr))
    need = np.maximum(np.maximum(deg[u], deg[v]),
                      np.maximum(out_deg[u], out_deg[v])).astype(np.int64)
    ks_arr = np.asarray(ks, dtype=np.int64)
    b = (need[:, None] > ks_arr[None, :]).sum(1)
    counts = np.bincount(b, minlength=len(ks))[: len(ks)].astype(np.int64)
    return counts, need[np.lexsort((need, b))] if with_needs else None


def make_census_fn(g: CSRGraph, *, batch: int = 256, K: int | None = None,
                   acc_dtype=jnp.int32):
    """Build a jitted census function for graphs with this one's metadata.

    The returned fn maps ``(graph_arrays, n, u, v, valid)`` — dyads already
    padded to a multiple of ``batch`` — to per-scan-step ``(steps, 16)``
    partials (summed on host in int64 to avoid 32-bit overflow, which is the
    static-shape analogue of the paper's per-thread census arrays).
    """
    K = K or max(1, g.max_deg)
    member_iters = max(1, math.ceil(math.log2(max(g.max_deg, g.max_out_deg, 1) + 1))) + 1
    batch_fn = make_census_batch_fn(K, member_iters, acc_dtype)

    @jax.jit
    def census(arrays: GraphArrays, n: jax.Array, u: jax.Array, v: jax.Array,
               valid: jax.Array):
        steps = u.shape[0] // batch
        u_b = u.reshape(steps, batch)
        v_b = v.reshape(steps, batch)
        val_b = valid.reshape(steps, batch)

        def step(carry, xs):
            uu, vv, va = xs
            return carry, batch_fn(arrays, n, uu, vv, va)

        _, partials = jax.lax.scan(step, 0, (u_b, v_b, val_b))
        return partials  # (steps, 16)

    return census


def triad_census(g: CSRGraph, *, batch: int = 256, K: int | None = None) -> CensusResult:
    """End-to-end single-device census with host int64 accumulation.

    .. deprecated:: use ``repro.engine.compile_census(g, config).run(g)`` —
       this shim forwards to the engine's "xla" backend (and therefore gets
       the plan cache and chunked streaming for free).
    """
    from ..engine import CensusConfig, compile_census

    warnings.warn(
        "repro.core.triad_census is deprecated; use "
        "repro.engine.compile_census(graph, CensusConfig(...)).run(graph)",
        DeprecationWarning, stacklevel=2)
    cfg = CensusConfig(backend="xla", batch=batch, k=K)
    return compile_census(g, cfg).run(g)


# ----------------------------------------------------------------------------
# Brute-force oracle (paper's naive O(n^3) algorithm) for tests.
# ----------------------------------------------------------------------------

def brute_force_census(g: CSRGraph) -> CensusResult:
    a = dense_adjacency(g).astype(np.int64)
    n = g.n
    idx = np.arange(n)
    counts = np.zeros(16, dtype=np.int64)
    # vectorize over (j, k) for each i to keep memory bounded
    for i in range(n - 2):
        j, k = np.meshgrid(idx, idx, indexing="ij")
        sel = (j > i) & (k > j)
        jj, kk = j[sel], k[sel]
        code = (
            a[i, jj] + 2 * a[jj, i] + 4 * a[i, kk] + 8 * a[kk, i]
            + 16 * a[jj, kk] + 32 * a[kk, jj]
        )
        counts += np.bincount(TRIAD_TABLE_64[code], minlength=16)
    return CensusResult(counts=counts)
