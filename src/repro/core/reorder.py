"""Locality-aware vertex reordering — the cache lever for a memory-bound pass.

The paper's thesis is that the triad census does "very little computation"
per byte and is dominated by unpredictable memory access (§6): every probe
walks CSR rows of essentially random vertices.  Tzul (arXiv 1807.03383)
shows that for exactly this class of problem, relabeling vertices so that
topological neighbors get nearby ids is the dominant cache optimization,
and Segura et al. (arXiv 2007.07131) make the coalescing argument for
GPUs — sorted, clustered neighborhoods turn scattered CSR gathers into
near-sequential reads.  Both apply to every backend here: the XLA binary
searches, the distributed shards, and the Pallas tile gather all index
``nbr_idx``/``out_idx`` by vertex id.

This module is pure host/NumPy and deterministic (no RNG, stable sorts):

* :func:`compute_permutation` — one of three shipped strategies:
  ``"degree"`` (hubs first — the degree-skew analogue of the paper's
  GPU degree-balancing), ``"bfs"`` (Gorder-style frontier order: each
  BFS level is laid out contiguously, hubs first within a level), and
  ``"rcm"`` (reverse Cuthill–McKee — the classic bandwidth minimizer).
* :func:`permute_graph` — relabel a :class:`~repro.core.graph.CSRGraph`
  through :func:`~repro.core.graph.from_edges`, so the reordered graph is
  bit-identical to one built from the relabeled edge list directly (same
  canonical sorted-CSR invariants, same metadata bucket).
* :func:`locality_score` — mean ``|u - v|`` over adjacency entries, the
  scalar the strategies are trying to shrink (reported by the benchmark).

Permutations follow the convention ``perm[old_id] = new_id``.  The engine
(:mod:`repro.engine.plan`) memoizes one permutation per (plan, graph),
runs all chunk dispatch on the relabeled graph, and maps raw bins back
through the inverse permutation, so results stay bit-identical for every
registered op (see ``GraphOp.unpermute_raw``).
"""
from __future__ import annotations

from collections import deque

import numpy as np

from .graph import CSRGraph, arcs_host, from_edges

__all__ = [
    "REORDER_STRATEGIES",
    "compute_permutation",
    "inverse_permutation",
    "locality_score",
    "permute_graph",
]

# Strategies that actually relabel; the engine-level knob adds "none".
REORDER_STRATEGIES = ("degree", "bfs", "rcm")


def _nbr_csr(g: CSRGraph):
    """Host views of the undirected-neighborhood CSR and degrees."""
    nbr_ptr = np.asarray(g.arrays.nbr_ptr)[: g.n + 1].astype(np.int64)
    nbr_idx = np.asarray(g.arrays.nbr_idx)[: g.m_nbr].astype(np.int64)
    deg = (nbr_ptr[1:] - nbr_ptr[:-1]).astype(np.int64)
    return nbr_ptr, nbr_idx, deg


def _degree_order(g: CSRGraph) -> np.ndarray:
    """New-id -> old-id order: descending undirected degree, ties by id.

    Stable and deterministic; packs the hubs (which dominate probe traffic
    on skewed graphs) into one contiguous, cache-resident id range.
    """
    _, _, deg = _nbr_csr(g)
    return np.lexsort((np.arange(g.n, dtype=np.int64), -deg))


def _bfs_order(g: CSRGraph) -> np.ndarray:
    """Gorder-style frontier order: BFS from the highest-degree unvisited
    vertex, each level laid out contiguously with hubs first within the
    level.  Restarts per connected component; isolated vertices (degree
    0) sort last and seed trivial components, so the order is total."""
    nbr_ptr, nbr_idx, deg = _nbr_csr(g)
    n = g.n
    visited = np.zeros(n, dtype=bool)
    order = np.empty(n, dtype=np.int64)
    pos = 0
    seeds = np.lexsort((np.arange(n, dtype=np.int64), -deg))
    si = 0
    while pos < n:
        while si < n and visited[seeds[si]]:
            si += 1
        root = seeds[si]
        visited[root] = True
        order[pos] = root
        pos += 1
        frontier = np.array([root], dtype=np.int64)
        while frontier.size:
            starts, counts = nbr_ptr[frontier], deg[frontier]
            total = int(counts.sum())
            if not total:
                break
            # vectorized multi-row gather (same idiom as affected_dyads)
            offs = (np.arange(total, dtype=np.int64)
                    - np.repeat(np.cumsum(counts) - counts, counts))
            nxt = np.unique(nbr_idx[np.repeat(starts, counts) + offs])
            nxt = nxt[~visited[nxt]]
            if not nxt.size:
                break
            nxt = nxt[np.lexsort((nxt, -deg[nxt]))]  # hubs first in level
            visited[nxt] = True
            order[pos : pos + nxt.size] = nxt
            pos += nxt.size
            frontier = nxt
    return order


def _rcm_order(g: CSRGraph) -> np.ndarray:
    """Reverse Cuthill–McKee: per component, breadth-first from a
    minimum-degree seed with neighbors enqueued in increasing-degree
    order, then the whole order reversed — the classic CSR bandwidth
    minimizer (George & Liu).  Deterministic: ties break by vertex id."""
    nbr_ptr, nbr_idx, deg = _nbr_csr(g)
    n = g.n
    visited = np.zeros(n, dtype=bool)
    order = np.empty(n, dtype=np.int64)
    pos = 0
    seeds = np.lexsort((np.arange(n, dtype=np.int64), deg))  # min-degree
    si = 0
    queue: deque[int] = deque()
    while pos < n:
        while si < n and visited[seeds[si]]:
            si += 1
        root = int(seeds[si])
        visited[root] = True
        queue.append(root)
        while queue:
            u = queue.popleft()
            order[pos] = u
            pos += 1
            nb = nbr_idx[nbr_ptr[u] : nbr_ptr[u + 1]]
            nb = nb[~visited[nb]]
            if nb.size:
                nb = nb[np.lexsort((nb, deg[nb]))]  # increasing degree
                visited[nb] = True
                queue.extend(int(w) for w in nb)
    return order[::-1].copy()


_ORDERS = {"degree": _degree_order, "bfs": _bfs_order, "rcm": _rcm_order}


def compute_permutation(g: CSRGraph, strategy: str) -> np.ndarray:
    """The vertex relabeling ``perm[old_id] = new_id`` for one strategy.

    Pure host-side and deterministic — same graph and strategy always
    yield the same permutation (stable sorts, id tie-breaks, no RNG) —
    so memoized reorderings replay exactly across runs and processes.
    Raises ``ValueError`` for unknown strategies.
    """
    if strategy not in _ORDERS:
        raise ValueError(
            f"unknown reorder strategy {strategy!r}: expected one of "
            f"{REORDER_STRATEGIES}")
    order = _ORDERS[strategy](g)  # order[new_id] = old_id
    return inverse_permutation(order)


def inverse_permutation(perm: np.ndarray) -> np.ndarray:
    """The inverse relabeling: ``inv[perm[i]] == i`` for all ``i``."""
    perm = np.asarray(perm, dtype=np.int64)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.size, dtype=np.int64)
    return inv


def permute_graph(g: CSRGraph, perm: np.ndarray) -> CSRGraph:
    """``g`` with vertex ``i`` relabeled to ``perm[i]`` — an isomorphic
    graph rebuilt through :func:`~repro.core.graph.from_edges`.

    Rebuilding (rather than gathering the CSR arrays in place) guarantees
    the relabeled graph satisfies every canonical invariant downstream
    code assumes — sorted CSR rows, deduplicated arcs, and the device-side
    transpose views built from them — and that all shape metadata
    (``n``/``m``/``m_nbr``/``max_deg``/``max_out_deg``) is preserved, so
    original and reordered graphs land in the SAME plan-cache bucket.
    """
    perm = np.asarray(perm, dtype=np.int64)
    if perm.shape != (g.n,):
        raise ValueError(f"permutation must have shape ({g.n},), got "
                         f"{perm.shape}")
    src, dst = arcs_host(g)
    g_p = from_edges(g.n, perm[src], perm[dst], directed=True)
    # relabeling cannot change counts or degree maxima
    assert (g_p.m, g_p.m_nbr, g_p.max_deg, g_p.max_out_deg) == (
        g.m, g.m_nbr, g.max_deg, g.max_out_deg)
    return g_p


def locality_score(g: CSRGraph) -> float:
    """Mean ``|u - v|`` over undirected adjacency entries — the average
    id distance a neighborhood gather spans (lower = more cache-local;
    0.0 for edgeless graphs).  This is the scalar ``"rcm"``/``"bfs"``
    minimize and the benchmark reports per strategy."""
    if g.m_nbr == 0:
        return 0.0
    nbr_ptr, nbr_idx, deg = _nbr_csr(g)
    rows = np.repeat(np.arange(g.n, dtype=np.int64), deg)
    return float(np.abs(rows - nbr_idx).mean())
