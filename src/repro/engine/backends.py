"""Backend execution strategies for the fused graph-analytic engine.

Each backend exposes the same contract to :mod:`repro.engine.plan`:

  * a ``make_*`` builder producing ONE compiled unit whose input shapes
    depend only on (graph-metadata buckets, op layout, config) — never on
    the actual dyad count — so a single trace serves every same-shape
    graph and every streaming chunk, and
  * a ``run_*`` driver that walks the canonical-dyad list in bounded-memory
    chunks and returns the fused raw int64 bins (one slice per op kernel —
    see :class:`repro.engine.ops.OpLayout`; host-side finalize lives in
    the ops).

The fused pass folds three kinds of contribution into one accumulator:

  * per-batch kernels (``OpLayout.batch_kernel``) — evaluated on every
    scan step of every chunk, concatenated across ops;
  * per-run ``once`` kernels (vertex-space analytics such as
    ``degree_stats``) — folded by the driver exactly once per run, into
    the on-device accumulator before the chunk loop;
  * the pallas census tile kernel, which fills the ``triad_census`` slice
    in place of that op's generic batch kernel on the pallas backend.

Two data paths exist per backend (``EngineConfig.device_accum``):

  * **device-resident (default)** — dyads are enumerated / bucketed / chunk
    -sliced on device and the fused partial counts accumulate **on
    device** across chunks as an int32 hi/lo pair (no x64 requirement).
    Chunk dispatch belongs to the plan's
    :class:`~repro.engine.executor.Executor`: the static schedule is the
    classic in-order double-buffered loop, the dynamic schedule carves
    the stream into cost-model chunks and work-queues them over a device
    pool.  Either way ONE device→host transfer completes the run — the
    paper's single end-of-run merge — *regardless of how many ops are
    fused or how many devices ran them* (the pallas bucket schedule is
    derived host-side, so even that backend pays no control fetch).
  * **synchronous baseline** — the PR-1 path: host numpy dyad slicing,
    per-chunk upload, and a blocking per-chunk device→host transfer with
    host int64 accumulation.  Kept runnable for A/B benchmarking
    (``benchmarks/run.py --sync-baseline``).

``plan.stats["host_syncs"]`` counts blocking device→host transfers so the
O(chunks) → O(1) claim is measurable, not asserted.

Closed forms (null triads/dyads, degree means) are applied by each op's
``finalize``, on host, after the chunk loop — backends only ever produce
the raw streamed/once bins.
"""
from __future__ import annotations

import functools
import math
import weakref
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import balance
from ..core.census import (canonical_dyads, enumerate_dyads_device,
                           host_bucket_schedule, pad_dyads,
                           sort_dyads_by_bucket)
from ..core.distributed import make_census_fn_for_mesh
from ..core.graph import CSRGraph, next_pow2
from .executor import ChunkTask, _acc_fetch, _acc_update


def _once_sync(plan, counts: np.ndarray, arrays, n) -> None:
    """Fold the per-run ``once`` contribution on the synchronous paths.

    The device-resident drivers fold it into the on-device accumulator
    before the chunk loop (:func:`_once_device`); the sync baselines
    fetch it once per run instead (counted — the baseline already pays
    one transfer per chunk).
    """
    once = plan.layout.once_jitted()
    if once is not None:
        counts += np.asarray(once(arrays, n), dtype=np.int64)
        plan.stats["host_syncs"] += 1


def _once_device(plan, hi, lo, arrays, n, *, batched: bool = False):
    """Fold the per-run ``once`` contribution on device, before the chunk
    loop — evaluated exactly once per run, so the chunk units never
    re-dispatch its vertex-space work, and nothing leaves the device (no
    counted sync)."""
    once = (plan.layout.once_batch_jitted() if batched
            else plan.layout.once_jitted())
    if once is None:
        return hi, lo
    return _acc_update(hi, lo, once(arrays, n))


class TaskStats(NamedTuple):
    """Lightweight per-shard load summary kept on the plan after a
    distributed run (the full ShardedTasks arrays are NOT retained — plans
    live forever in the cache and must not pin graph-sized host memory)."""

    weights: np.ndarray  # (n_shards,) modeled per-shard work
    strategy: str
    weight_model: str
    shape: tuple  # (n_shards, L) of the task arrays

    @property
    def imbalance(self) -> float:
        mean = self.weights.mean()
        return float(self.weights.max() / mean) if mean > 0 else 1.0

# ----------------------------------------------------------------------------
# xla: binary-search scan backend (single device)
# ----------------------------------------------------------------------------


def make_xla_chunk_fn(layout, config, stats: dict):
    """Jitted ``(arrays, n, u, v, valid) -> (steps, total_bins)`` per chunk.

    The synchronous-baseline unit: ``u/v/valid`` arrive padded to
    ``config.resolve_chunk()`` dyads, so the trace is reused across chunks
    and across same-bucket graphs; ``stats['traces']`` counts actual
    retraces (trace-time side effect).  Each scan step evaluates the fused
    multi-op batch kernel.
    """
    batch = config.batch
    fused = layout.batch_kernel()

    @jax.jit
    def chunk_fn(arrays, n, u, v, valid):
        stats["traces"] += 1
        steps = u.shape[0] // batch

        def step(carry, xs):
            uu, vv, va = xs
            return carry, fused(arrays, n, uu, vv, va)

        _, partials = jax.lax.scan(
            step, 0, (u.reshape(steps, batch), v.reshape(steps, batch),
                      valid.reshape(steps, batch)))
        return partials  # (steps, total_bins)

    return chunk_fn


def _xla_stream_body(layout, config, chunk: int):
    """Single-graph chunk body shared by the scalar and batched xla units.

    ``(arrays, n, dyads_u, dyads_v, limit, start, hi, lo) -> (hi, lo)``:
    the dyad span ``[start, limit)`` is carved out of the device-resident
    dyad list with ``dynamic_slice`` and its fused partial counts fold
    into the carried hi/lo accumulator per scan step (per-run ``once``
    contributions are the driver's job — :func:`_once_device` — so no
    chunk re-dispatches vertex-space work).  The gather window is
    anchored at ``min(start, len(dyads) - chunk)`` and lanes outside
    ``[start, limit)`` are masked invalid, so cost-model chunk
    boundaries (any ``start``, any span length up to ``chunk`` — the
    executor's dynamic schedule) stay in bounds, and a graph whose dyad
    list is shorter than the chunk schedule contributes exactly nothing
    for the excess chunks — that is what makes the vmapped batch unit
    (which passes the per-graph dyad count as ``limit``) bit-identical
    to sequential runs.
    """
    batch = config.batch
    fused = layout.batch_kernel()

    def body(arrays, n, du, dv, limit, start, hi, lo):
        base = jnp.minimum(start, du.shape[0] - chunk)
        pos = base + jnp.arange(chunk, dtype=jnp.int32)
        u = jax.lax.dynamic_slice(du, (base,), (chunk,))
        v = jax.lax.dynamic_slice(dv, (base,), (chunk,))
        valid = (pos >= start) & (pos < limit)
        u = jnp.where(valid, u, 0)
        v = jnp.where(valid, v, 1)  # keep the u < v padding invariant
        steps = chunk // batch

        def step(carry, xs):
            uu, vv, va = xs
            h, l = carry
            return _acc_update(h, l, fused(arrays, n, uu, vv, va)), None

        (hi, lo), _ = jax.lax.scan(
            step, (hi, lo),
            (u.reshape(steps, batch), v.reshape(steps, batch),
             valid.reshape(steps, batch)))
        return hi, lo

    return body


def make_xla_stream_fn(layout, config, stats: dict, chunk: int):
    """Device-resident unit: slice + fused kernels + accumulate, one
    dispatch.

    ``(arrays, n, dyads_u, dyads_v, limit, start, hi, lo) -> (hi, lo)``.
    The full (bucket-padded) dyad list stays on device; the host only ever
    dispatches (see :func:`_xla_stream_body`).  One ``jax.jit`` callable
    serves every executor pool device — jit caches one compiled replica
    per committed input device.
    """
    body = _xla_stream_body(layout, config, chunk)

    @jax.jit
    def stream_fn(arrays, n, du, dv, limit, start, hi, lo):
        stats["traces"] += 1
        return body(arrays, n, du, dv, limit, start, hi, lo)

    return stream_fn


def make_xla_stream_batch_fn(layout, config, stats: dict, chunk: int):
    """Batched device-resident unit: one dispatch covers B graphs.

    The vmap of :func:`_xla_stream_body` over a leading batch axis on the
    padded graph arrays, the dyad lists, ``n``/``n_dyads`` and the fused
    hi/lo accumulator; ``start`` (the chunk cursor) is shared across the
    batch.  Every same-bucket graph has identical padded shapes, so one
    trace per batch size serves the whole fleet — and because every op is
    pure int32/int64 arithmetic, each graph's lane computes exactly the
    per-graph result (``run_batch`` is bit-identical to sequential
    ``run`` calls).
    """
    body = jax.vmap(_xla_stream_body(layout, config, chunk),
                    in_axes=(0, 0, 0, 0, 0, None, 0, 0))

    @jax.jit
    def stream_batch_fn(arrays, n, du, dv, n_dyads, start, hi, lo):
        stats["traces"] += 1
        return body(arrays, n, du, dv, n_dyads, start, hi, lo)

    return stream_batch_fn


def _memo_tasks(plan, g: CSRGraph, key, build) -> "list[ChunkTask]":
    """Per-plan memo of a host-derived chunk schedule.

    The task list is a pure function of ``(graph, key)`` but costs O(m)
    host preprocessing (dyad enumeration, degree weights, sorts) — pay
    it once per live graph, not once per run, since plans exist exactly
    to amortize per-run setup (the serving hot path reruns the same
    graphs).  Keys carry ``id(g)`` plus a weakref identity check, so a
    recycled id after GC can never serve a stale schedule; the memo is
    bounded to the last few graphs (plans live forever in the LRU cache
    and must not pin unbounded host memory).
    """
    full_key = (key, id(g))
    hit = plan._task_memo.get(full_key)
    if hit is not None and hit[0]() is g:
        return hit[1]
    tasks = build()
    while len(plan._task_memo) >= 8:
        plan._task_memo.pop(next(iter(plan._task_memo)))
    plan._task_memo[full_key] = (weakref.ref(g), tasks)
    return tasks


def _dyad_tasks(plan, g: CSRGraph, chunk=None) -> "list[ChunkTask]":
    """Chunk schedule over the dyad stream ``[0, n_dyads)``.

    Static: the fixed-size grid — bit-identical to the pre-executor
    engine.  Dynamic: cost-model boundaries — per-dyad degree weights
    (``config.weight_model``, the paper's Table 4.8 cost models) drive
    equal-predicted-work spans via
    :func:`repro.core.balance.chunk_bounds_by_cost`, so heavy-degree
    regions of the stream get smaller chunks.  The weights are host-side
    preprocessing, exactly like the paper's precomputed task weights
    (host dyad order matches the device enumeration bit for bit — see
    ``tests/test_pipeline.py::test_device_enumeration_matches_host``),
    memoized per graph (:func:`_memo_tasks`).
    """
    chunk = chunk or plan.chunk
    if plan.config.schedule == "dynamic" and g.n_dyads:
        def build():
            u, v = canonical_dyads(g)
            w = balance.dyad_weights(g, u, v, plan.config.weight_model)
            bounds = balance.chunk_bounds_by_cost(w, chunk)
            cum = np.concatenate([[0.0], np.cumsum(w, dtype=np.float64)])
            return [ChunkTask(int(a), int(b), float(cum[b] - cum[a]))
                    for a, b in zip(bounds[:-1], bounds[1:])]

        return _memo_tasks(plan, g, ("dyads", chunk), build)
    return [ChunkTask(s, min(s + chunk, g.n_dyads),
                      float(min(s + chunk, g.n_dyads) - s))
            for s in range(0, g.n_dyads, chunk)]


def _run_xla_sync(plan, g: CSRGraph) -> np.ndarray:
    u, v = canonical_dyads(g)
    counts = np.zeros(plan.layout.total_bins, dtype=np.int64)
    if not len(u):
        return counts
    chunk = plan.chunk
    arrays = plan.padded_arrays(g)
    n = jnp.int32(g.n)
    _once_sync(plan, counts, arrays, n)
    for s in range(0, len(u), chunk):
        uu, vv, valid = pad_dyads(u[s:s + chunk], v[s:s + chunk], chunk)
        partials = plan._fn(arrays, n, jnp.asarray(uu), jnp.asarray(vv),
                            jnp.asarray(valid))
        counts += np.asarray(partials, dtype=np.int64).sum(0)
        plan.stats["chunks"] += 1
        plan.stats["host_syncs"] += 1
    return counts


def run_xla(plan, g: CSRGraph) -> np.ndarray:
    if not plan.device_path:
        return _run_xla_sync(plan, g)
    if g.n_dyads == 0:
        return np.zeros(plan.layout.total_bins, dtype=np.int64)
    arrays = plan.padded_arrays(g)
    du, dv = enumerate_dyads_device(arrays.nbr_ptr, arrays.nbr_idx,
                                    jnp.int32(g.m_nbr),
                                    out_size=plan.dyad_pad)
    n = jnp.int32(g.n)
    hi = lo = jnp.zeros(plan.layout.total_bins, jnp.int32)
    init = _once_device(plan, hi, lo, arrays, n)

    def place(dev):
        ctx = (arrays, n, du, dv)
        return ctx if dev is None else jax.device_put(ctx, dev)

    def step(ctx, hi, lo, t):
        a, nn, su, sv = ctx
        return plan._fn(a, nn, su, sv, jnp.int32(t.end), jnp.int32(t.start),
                        hi, lo)

    hi, lo = plan.executor.run(_dyad_tasks(plan, g), place=place, step=step,
                               init=init)
    return _acc_fetch(plan, hi, lo)


def run_xla_batch(plan, graphs) -> np.ndarray:
    """Vmapped device-resident fused pass over B same-bucket graphs.

    Returns ``(B, total_bins)`` int64 raw bins (per-op closed forms are
    applied per graph by ``Plan.run_batch`` via the op finalizers).  The
    batch is padded up to a power of two with inert entries (``m_nbr = 0``
    and ``n = 0``, so every chunk lane and every once contribution is
    masked out) to bound the number of batch shapes the jitted unit ever
    traces; the chunk schedule covers the largest dyad count in the batch,
    shorter graphs no-op on the excess chunks.  One device→host transfer
    completes the whole batch.
    """
    from ..core.graph import stack_graph_arrays

    B = len(graphs)
    max_dyads = max(g.n_dyads for g in graphs)
    if max_dyads == 0:
        return np.zeros((B, plan.layout.total_bins), dtype=np.int64)
    pad = next_pow2(B) - B
    hosts = [plan.padded_arrays_host(g) for g in graphs]
    arrays = stack_graph_arrays(hosts + [hosts[0]] * pad)
    m_nbr = jnp.asarray([g.m_nbr for g in graphs] + [0] * pad, jnp.int32)
    n = jnp.asarray([g.n for g in graphs] + [0] * pad, jnp.int32)
    n_dyads = jnp.asarray([g.n_dyads for g in graphs] + [0] * pad, jnp.int32)
    enum = jax.vmap(functools.partial(enumerate_dyads_device,
                                      out_size=plan.dyad_pad))
    du, dv = enum(arrays.nbr_ptr, arrays.nbr_idx, m_nbr)
    hi = lo = jnp.zeros((B + pad, plan.layout.total_bins), jnp.int32)
    init = _once_device(plan, hi, lo, arrays, n, batched=True)
    fn = plan.batch_fn()
    chunk = plan.chunk

    def place(dev):
        ctx = (arrays, n, du, dv, n_dyads)
        return ctx if dev is None else jax.device_put(ctx, dev)

    def step(ctx, hi, lo, t):
        # the batched unit masks by per-graph dyad count (the vmapped
        # ``limit`` axis), so the task's ``end`` is schedule metadata only.
        a, nn, su, sv, nd = ctx
        return fn(a, nn, su, sv, nd, jnp.int32(t.start), hi, lo)

    tasks = [ChunkTask(s, min(s + chunk, max_dyads), float(chunk))
             for s in range(0, max_dyads, chunk)]
    hi, lo = plan.executor.run(tasks, place=place, step=step, init=init)
    return _acc_fetch(plan, hi, lo)[:B]


# ----------------------------------------------------------------------------
# distributed: shard_map SPMD backend
# ----------------------------------------------------------------------------


def make_distributed_chunk_fn(layout, config, mesh, stats: dict):
    """Jitted shard_map'd ``(arrays, n, u, v, valid) -> (total_bins,)``
    per chunk.

    Task arrays are ``(n_devices, chunk_L)``; each device scans its local
    ``(1, chunk_L)`` slice through the fused multi-op batch kernel and one
    psum per mesh axis performs the paper's end-of-run merge (the only
    communication in the whole job).  The SPMD schedule itself is
    :func:`repro.core.distributed.make_census_fn_for_mesh`, parameterized
    by the fused kernel.
    """

    def on_trace():
        stats["traces"] += 1

    return make_census_fn_for_mesh(
        mesh, batch=config.batch, acc_dtype=config.acc_jnp_dtype,
        on_trace=on_trace, batch_fn=layout.batch_kernel(),
        n_bins=layout.total_bins)


def make_distributed_stream_fn(layout, config, mesh, stats: dict):
    """Device-resident unit: shard_map fused pass + on-device hi/lo fold.

    ``(arrays, n, u, v, valid, hi, lo) -> (hi, lo)`` where ``u/v/valid``
    are ``(n_devices, chunk_L)`` slabs carved from the device-resident
    task arrays by the driver (an eager device-side ``dynamic_slice`` —
    no host staging; per-run ``once`` contributions are folded by the
    driver before the chunk loop).  The psum'd per-chunk counts never
    leave the device.
    """
    inner = make_distributed_chunk_fn(layout, config, mesh, stats)

    @jax.jit
    def stream_fn(arrays, n, u, v, valid, hi, lo):
        return _acc_update(hi, lo, inner(arrays, n, u, v, valid))

    return stream_fn


def chunk_l(plan) -> int:
    """Per-device streaming chunk length (multiple of ``batch``)."""
    n_dev = math.prod(plan.mesh.devices.shape)
    batch = plan.config.batch
    per_dev = max(1, plan.chunk // n_dev)
    return max(batch, ((per_dev + batch - 1) // batch) * batch)


def run_distributed(plan, g: CSRGraph) -> np.ndarray:
    cfg = plan.config
    n_dev = math.prod(plan.mesh.devices.shape)
    counts = np.zeros(plan.layout.total_bins, dtype=np.int64)
    tasks = balance.pack_tasks(g, n_dev, weight_model=cfg.weight_model,
                               strategy=cfg.strategy, pad_multiple=cfg.batch)
    plan.last_task_stats = TaskStats(weights=tasks.weights,
                                     strategy=tasks.strategy,
                                     weight_model=tasks.weight_model,
                                     shape=tasks.u.shape)
    if g.n_dyads == 0:
        return counts
    cl = chunk_l(plan)
    L = tasks.u.shape[1]
    pad = (-L) % cl
    tu = np.pad(tasks.u, ((0, 0), (0, pad)))
    tv = np.pad(tasks.v, ((0, 0), (0, pad)), constant_values=1)
    tval = np.pad(tasks.valid, ((0, 0), (0, pad)))
    arrays = plan.padded_arrays(g)
    n = jnp.int32(g.n)
    if not plan.device_path:
        _once_sync(plan, counts, arrays, n)
        for s in range(0, L + pad, cl):
            c = plan._fn(arrays, n, jnp.asarray(tu[:, s:s + cl]),
                         jnp.asarray(tv[:, s:s + cl]),
                         jnp.asarray(tval[:, s:s + cl]))
            counts += np.asarray(c, dtype=np.int64)
            plan.stats["chunks"] += 1
            plan.stats["host_syncs"] += 1
        return counts
    # device path: ONE upload of the packed task arrays, then device-side
    # slab slicing + on-device accumulation; one transfer at the end.
    dtu, dtv, dtval = jnp.asarray(tu), jnp.asarray(tv), jnp.asarray(tval)
    hi = lo = jnp.zeros(plan.layout.total_bins, jnp.int32)
    init = _once_device(plan, hi, lo, arrays, n)

    def place(dev):
        # the mesh already owns every device (the executor pool is pinned
        # to one slot for this backend), so placement stays with shard_map.
        return (arrays, n, dtu, dtv, dtval)

    def step(ctx, hi, lo, t):
        a, nn, qu, qv, qval = ctx
        su = jax.lax.dynamic_slice(qu, (0, t.start), (n_dev, cl))
        sv = jax.lax.dynamic_slice(qv, (0, t.start), (n_dev, cl))
        sva = jax.lax.dynamic_slice(qval, (0, t.start), (n_dev, cl))
        return plan._fn(a, nn, su, sv, sva, hi, lo)

    # slab columns carry near-uniform modeled work already (pack_tasks
    # balanced them), so the task grid stays fixed-size on this backend.
    tasks = [ChunkTask(s, s + cl, float(cl * n_dev))
             for s in range(0, L + pad, cl)]
    hi, lo = plan.executor.run(tasks, place=place, step=step, init=init)
    return _acc_fetch(plan, hi, lo)


# ----------------------------------------------------------------------------
# pallas: degree-bucketed VMEM tile kernel backend
# ----------------------------------------------------------------------------


def make_pallas_chunk_fn(layout, config):
    """Fused device chunk unit for the pallas backend.

    ``(arrays, n, su, sv, start, end, hi, lo; K, chunk, block, interpret)``:
    slice the bucket-sorted dyad list, gather VMEM tiles and run the
    census tile kernel into the ``triad_census`` accumulator slice, and
    run every other op's generic batch kernel on the same chunk of dyads
    — one dispatch, zero host staging (per-run ``once`` contributions are
    folded by the driver before the chunk loop).  Ops other than the
    census don't need the tiles, so the one expensive gather is paid
    exactly once per chunk for the whole op set.
    """
    from ..kernels import ops as kops
    from ..kernels.triad_census import SENTINEL, census_tiles_pallas

    census_sl = layout.slices.get("triad_census")
    rest = (layout.batch_kernel(skip=("triad_census",))
            if layout.has_batch(skip=("triad_census",)) else None)
    total = layout.total_bins

    @functools.partial(jax.jit,
                       static_argnames=("K", "chunk", "block", "interpret"))
    def pallas_chunk(arrays, n, su, sv, start, end, hi, lo, *, K: int,
                     chunk: int, block: int, interpret: bool):
        pos = start + jnp.arange(chunk, dtype=jnp.int32)
        valid = pos < end
        u = jnp.take(su, pos, mode="clip")
        v = jnp.take(sv, pos, mode="clip")
        if rest is not None:
            hi, lo = _acc_update(
                hi, lo, rest(arrays, n, jnp.where(valid, u, 0),
                             jnp.where(valid, v, 1), valid))
        if census_sl is not None:
            tiles = kops.gather_tiles_device(arrays, u, v, valid, K=K)
            parts = census_tiles_pallas(
                jnp.where(valid, u, SENTINEL), jnp.where(valid, v, SENTINEL),
                n, *(tiles[k] for k in ("out_u", "in_u", "out_v", "in_v",
                                        "nbr_u", "nbr_v")),
                block=block, interpret=interpret, reduce=False)

            def fold(carry, p):
                h, l = carry
                full = jnp.zeros((total,), p.dtype).at[census_sl].set(p)
                return _acc_update(h, l, full), None

            (hi, lo), _ = jax.lax.scan(fold, (hi, lo), parts)
        return hi, lo

    return pallas_chunk


def _run_pallas_sync(plan, g: CSRGraph) -> np.ndarray:
    from ..kernels import ops
    from ..kernels.triad_census import SENTINEL, census_tiles_pallas

    cfg = plan.config
    layout = plan.layout
    interpret = cfg.resolve_interpret()
    block = cfg.resolve_block()
    u, v = canonical_dyads(g)
    counts = np.zeros(layout.total_bins, dtype=np.int64)
    if not len(u):
        return counts
    census_sl = layout.slices.get("triad_census")
    rest = (layout.batch_kernel(skip=("triad_census",))
            if layout.has_batch(skip=("triad_census",)) else None)
    n_dev = jnp.int32(g.n)
    if plan.layout.has_once:
        # padded (bucket-shaped) arrays: the layout-cached jitted once
        # kernel must see one shape per plan, not one per concrete graph.
        _once_sync(plan, counts, plan.padded_arrays(g), n_dev)
    # transpose CSR, built once per run — tile building only, so skipped
    # when no op uses the census tile kernel.
    in_csr = ops.build_in_csr(g) if census_sl is not None else None
    deg = np.asarray(g.arrays.nbr_deg)
    out_deg = np.diff(np.asarray(g.arrays.out_ptr))
    need = np.maximum(np.maximum(deg[u], deg[v]),
                      np.maximum(out_deg[u], out_deg[v]))
    kmax = max(g.max_deg, 1)
    ks = sorted({min(max(int(k), 1), kmax) for k in cfg.buckets} | {kmax})
    chunk = max(block, (plan.chunk // block) * block)
    assigned = np.zeros(len(u), bool)
    for K in ks:
        sel = (~assigned) & (need <= K)
        assigned |= sel
        if not sel.any():
            continue
        uu_all, vv_all = u[sel], v[sel]
        # stream this bucket in bounded chunks: only (chunk, K) tiles are
        # ever resident on host or device at once.
        for s in range(0, len(uu_all), chunk):
            uu = uu_all[s:s + chunk]
            vv = vv_all[s:s + chunk]
            if rest is not None:
                # generic ops see the exact chunk dyads (no tiles needed);
                # eager evaluation, one small transfer per chunk — the
                # sync baseline already pays one per chunk for the census.
                ru, rv, rva = pad_dyads(uu, vv, chunk)
                counts += np.asarray(
                    rest(g.arrays, n_dev, jnp.asarray(ru), jnp.asarray(rv),
                         jnp.asarray(rva)), dtype=np.int64)
                plan.stats["host_syncs"] += 1
            if census_sl is None:
                plan.stats["chunks"] += 1
                continue
            pad = (-len(uu)) % block
            if pad:
                uu = np.concatenate([uu, np.full(pad, SENTINEL, np.int32)])
                vv = np.concatenate([vv, np.full(pad, SENTINEL, np.int32)])
            tiles = ops.build_tiles(g, np.clip(uu, 0, g.n - 1).astype(np.int64),
                                    np.clip(vv, 0, g.n - 1).astype(np.int64),
                                    K, in_csr=in_csr)
            if pad:  # padded dyads: blank their tiles
                for t in tiles.values():
                    t[-pad:] = SENTINEL
            part = census_tiles_pallas(
                jnp.asarray(uu), jnp.asarray(vv), g.n,
                *(jnp.asarray(tiles[k]) for k in
                  ("out_u", "in_u", "out_v", "in_v", "nbr_u", "nbr_v")),
                block=block, interpret=interpret)
            counts[census_sl] += np.asarray(part, dtype=np.int64)
            plan.stats["chunks"] += 1
            plan.stats["host_syncs"] += 1
    return counts


def run_pallas(plan, g: CSRGraph) -> np.ndarray:
    if not plan.device_path:
        return _run_pallas_sync(plan, g)
    cfg = plan.config
    if g.n_dyads == 0:
        return np.zeros(plan.layout.total_bins, dtype=np.int64)
    interpret = cfg.resolve_interpret()
    block = cfg.resolve_block()
    chunk = max(block, (plan.chunk // block) * block)
    # top bucket = the plan's bucketized tile width (NOT the exact max
    # degree): every static shape below is then a pure function of the
    # plan-cache key, so same-bucket graphs reuse the compiled pipeline.
    kmax = max(plan.meta.k, 1)
    ks = tuple(sorted({min(max(int(k), 1), kmax)
                       for k in cfg.buckets} | {kmax}))
    # the tile kernel's whole support system — device-built transpose CSR,
    # degree-bucket sort, and the host-derived bucket schedule — only
    # exists for the census slice; a plan of generic ops skips all three.
    census_needed = "triad_census" in plan.layout.slices
    arrays = plan.padded_arrays(g, with_in_csr=census_needed)
    du, dv = enumerate_dyads_device(arrays.nbr_ptr, arrays.nbr_idx,
                                    jnp.int32(g.m_nbr),
                                    out_size=plan.dyad_pad)
    n = jnp.int32(g.n)
    hi = lo = jnp.zeros(plan.layout.total_bins, jnp.int32)
    init = _once_device(plan, hi, lo, arrays, n)
    if not census_needed:
        stream_u, stream_v = du, dv
        tasks = [t._replace(key=kmax)
                 for t in _dyad_tasks(plan, g, chunk=chunk)]
    else:
        stream_u, stream_v, _ = sort_dyads_by_bucket(
            arrays.nbr_deg, arrays.out_ptr, du, dv, jnp.int32(g.n_dyads),
            ks=ks)
        # the per-bucket schedule used to be a device→host control fetch
        # of the sort's bucket counts — the extra counted sync the other
        # backends never paid, and it stalled dispatch until the device
        # sort finished.  The counts are a pure function of the degree
        # arrays the host already owns, so derive them (and the per-dyad
        # tile-width needs, the dynamic schedule's cost model) host-side.
        tasks = _pallas_bucket_tasks(plan, g, ks, chunk)

    def place(dev):
        ctx = (arrays, n, stream_u, stream_v)
        return ctx if dev is None else jax.device_put(ctx, dev)

    def step(ctx, hi, lo, t):
        a, nn, su, sv = ctx
        return plan._fn(a, nn, su, sv, jnp.int32(t.start), jnp.int32(t.end),
                        hi, lo, K=int(t.key), chunk=chunk, block=block,
                        interpret=interpret)

    hi, lo = plan.executor.run(tasks, place=place, step=step, init=init)
    return _acc_fetch(plan, hi, lo)


def _pallas_bucket_tasks(plan, g: CSRGraph, ks: tuple,
                         chunk: int) -> "list[ChunkTask]":
    """Per-bucket chunk schedule over the bucket-sorted dyad stream.

    Each task carries its bucket's tile width ``K`` (the pallas kernel's
    static specialization).  Static: the fixed-size grid within every
    bucket, bit-identical to the pre-executor loop.  Dynamic: per-dyad
    tile-width needs are the cost model — a span's predicted work is the
    sum of its needs against one stream-wide quota, so big-K buckets get
    proportionally smaller chunks (the paper's degree-based GPU load
    balancing, applied to the chunk schedule itself).  Memoized per
    graph (:func:`_memo_tasks`) — the bucket counts replaced a per-run
    device control fetch and must stay cheaper than it on repeat runs.
    """
    def build():
        dynamic = plan.config.schedule == "dynamic"
        bucket_counts, need_sorted = host_bucket_schedule(
            g, ks, with_needs=dynamic)
        if dynamic:
            cum = np.concatenate([[0.0],
                                  np.cumsum(need_sorted, dtype=np.float64)])
            target = cum[-1] / max(1, -(-g.n_dyads // chunk))
        tasks: list = []
        offset = 0
        for i, K in enumerate(ks):
            c = int(bucket_counts[i])
            if dynamic and c:
                bounds = offset + balance.chunk_bounds_by_cost(
                    need_sorted[offset:offset + c], chunk, target=target)
                tasks += [ChunkTask(int(a), int(b), float(cum[b] - cum[a]),
                                    K)
                          for a, b in zip(bounds[:-1], bounds[1:])]
            else:
                tasks += [ChunkTask(s, offset + c,
                                    float(K * min(chunk, offset + c - s)), K)
                          for s in range(offset, offset + c, chunk)]
            offset += c
        return tasks

    return _memo_tasks(plan, g, ("pallas", ks, chunk), build)


#: backend-name → full-pass runner, the single dispatch table
#: :meth:`repro.engine.plan.Plan._run_raw` (and its degradation ladder)
#: executes through — a demoted plan re-enters here under its new rung.
RUNNERS = {"xla": run_xla, "distributed": run_distributed,
           "pallas": run_pallas}
