"""Backend execution strategies for the census engine.

Each backend exposes the same contract to :mod:`repro.engine.plan`:

  * an optional ``make_*_chunk_fn`` building ONE compiled unit whose input
    shapes depend only on (graph-metadata buckets, config) — never on the
    actual dyad count — so a single trace serves every same-shape graph and
    every streaming chunk, and
  * a ``run_*`` loop that walks the canonical-dyad list in bounded-memory
    chunks, feeding the compiled unit and accumulating int64 partials on the
    host (the paper's decoupled census arrays + single final merge).

The null-triad (type 003) closed form is applied once, in plan.py, after
the chunk loop — backends only ever produce connected + dyadic counts.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import balance
from ..core.census import canonical_dyads, make_census_batch_fn, pad_dyads
from ..core.distributed import make_census_fn_for_mesh
from ..core.graph import CSRGraph


class TaskStats(NamedTuple):
    """Lightweight per-shard load summary kept on the plan after a
    distributed run (the full ShardedTasks arrays are NOT retained — plans
    live forever in the cache and must not pin graph-sized host memory)."""

    weights: np.ndarray  # (n_shards,) modeled per-shard work
    strategy: str
    weight_model: str
    shape: tuple  # (n_shards, L) of the task arrays

    @property
    def imbalance(self) -> float:
        mean = self.weights.mean()
        return float(self.weights.max() / mean) if mean > 0 else 1.0

# ----------------------------------------------------------------------------
# xla: binary-search scan backend (single device)
# ----------------------------------------------------------------------------


def make_xla_chunk_fn(meta, config, stats: dict):
    """Jitted ``(arrays, n, u, v, valid) -> (steps, 16)`` over one chunk.

    ``u/v/valid`` always arrive padded to ``config.resolve_chunk()`` dyads,
    so the trace is reused across chunks and across same-bucket graphs;
    ``stats['traces']`` counts actual retraces (trace-time side effect).
    """
    batch = config.batch
    batch_fn = make_census_batch_fn(meta.k, meta.member_iters,
                                    config.acc_jnp_dtype)

    @jax.jit
    def chunk_fn(arrays, n, u, v, valid):
        stats["traces"] += 1
        steps = u.shape[0] // batch

        def step(carry, xs):
            uu, vv, va = xs
            return carry, batch_fn(arrays, n, uu, vv, va)

        _, partials = jax.lax.scan(
            step, 0, (u.reshape(steps, batch), v.reshape(steps, batch),
                      valid.reshape(steps, batch)))
        return partials  # (steps, 16)

    return chunk_fn


def run_xla(plan, g: CSRGraph) -> np.ndarray:
    u, v = canonical_dyads(g)
    counts = np.zeros(16, dtype=np.int64)
    if not len(u):
        return counts
    chunk = plan.chunk
    arrays = plan.padded_arrays(g)
    n = jnp.int32(g.n)
    for s in range(0, len(u), chunk):
        uu, vv, valid = pad_dyads(u[s:s + chunk], v[s:s + chunk], chunk)
        partials = plan._fn(arrays, n, jnp.asarray(uu), jnp.asarray(vv),
                            jnp.asarray(valid))
        counts += np.asarray(partials, dtype=np.int64).sum(0)
        plan.stats["chunks"] += 1
    return counts


# ----------------------------------------------------------------------------
# distributed: shard_map SPMD backend
# ----------------------------------------------------------------------------


def make_distributed_chunk_fn(meta, config, mesh, stats: dict):
    """Jitted shard_map'd ``(arrays, n, u, v, valid) -> (16,)`` per chunk.

    Task arrays are ``(n_devices, chunk_L)``; each device scans its local
    ``(1, chunk_L)`` slice and one psum per mesh axis performs the paper's
    end-of-run merge (the only communication in the whole job).  The SPMD
    schedule itself is :func:`repro.core.distributed.make_census_fn_for_mesh`.
    """

    def on_trace():
        stats["traces"] += 1

    return make_census_fn_for_mesh(
        mesh, K=meta.k, member_iters=meta.member_iters, batch=config.batch,
        acc_dtype=config.acc_jnp_dtype, on_trace=on_trace)


def chunk_l(plan) -> int:
    """Per-device streaming chunk length (multiple of ``batch``)."""
    n_dev = math.prod(plan.mesh.devices.shape)
    batch = plan.config.batch
    per_dev = max(1, plan.chunk // n_dev)
    return max(batch, ((per_dev + batch - 1) // batch) * batch)


def run_distributed(plan, g: CSRGraph) -> np.ndarray:
    cfg = plan.config
    n_dev = math.prod(plan.mesh.devices.shape)
    counts = np.zeros(16, dtype=np.int64)
    tasks = balance.pack_tasks(g, n_dev, weight_model=cfg.weight_model,
                               strategy=cfg.strategy, pad_multiple=cfg.batch)
    plan.last_task_stats = TaskStats(weights=tasks.weights,
                                     strategy=tasks.strategy,
                                     weight_model=tasks.weight_model,
                                     shape=tasks.u.shape)
    if g.n_dyads == 0:
        return counts
    cl = chunk_l(plan)
    L = tasks.u.shape[1]
    pad = (-L) % cl
    tu = np.pad(tasks.u, ((0, 0), (0, pad)))
    tv = np.pad(tasks.v, ((0, 0), (0, pad)), constant_values=1)
    tval = np.pad(tasks.valid, ((0, 0), (0, pad)))
    arrays = plan.padded_arrays(g)
    n = jnp.int32(g.n)
    for s in range(0, L + pad, cl):
        c = plan._fn(arrays, n, jnp.asarray(tu[:, s:s + cl]),
                     jnp.asarray(tv[:, s:s + cl]),
                     jnp.asarray(tval[:, s:s + cl]))
        counts += np.asarray(c, dtype=np.int64)
        plan.stats["chunks"] += 1
    return counts


# ----------------------------------------------------------------------------
# pallas: degree-bucketed VMEM tile kernel backend
# ----------------------------------------------------------------------------


def run_pallas(plan, g: CSRGraph) -> np.ndarray:
    from ..kernels import ops
    from ..kernels.triad_census import SENTINEL, census_tiles_pallas

    cfg = plan.config
    interpret = cfg.resolve_interpret()
    block = cfg.resolve_block()
    u, v = canonical_dyads(g)
    counts = np.zeros(16, dtype=np.int64)
    if not len(u):
        return counts
    in_csr = ops.build_in_csr(g)  # transpose CSR, built once per run
    deg = np.asarray(g.arrays.nbr_deg)
    out_deg = np.diff(np.asarray(g.arrays.out_ptr))
    need = np.maximum(np.maximum(deg[u], deg[v]),
                      np.maximum(out_deg[u], out_deg[v]))
    kmax = max(g.max_deg, 1)
    ks = sorted({min(max(int(k), 1), kmax) for k in cfg.buckets} | {kmax})
    chunk = max(block, (plan.chunk // block) * block)
    assigned = np.zeros(len(u), bool)
    for K in ks:
        sel = (~assigned) & (need <= K)
        assigned |= sel
        if not sel.any():
            continue
        uu_all, vv_all = u[sel], v[sel]
        # stream this bucket in bounded chunks: only (chunk, K) tiles are
        # ever resident on host or device at once.
        for s in range(0, len(uu_all), chunk):
            uu = uu_all[s:s + chunk]
            vv = vv_all[s:s + chunk]
            pad = (-len(uu)) % block
            if pad:
                uu = np.concatenate([uu, np.full(pad, SENTINEL, np.int32)])
                vv = np.concatenate([vv, np.full(pad, SENTINEL, np.int32)])
            tiles = ops.build_tiles(g, np.clip(uu, 0, g.n - 1).astype(np.int64),
                                    np.clip(vv, 0, g.n - 1).astype(np.int64),
                                    K, in_csr=in_csr)
            if pad:  # padded dyads: blank their tiles
                for t in tiles.values():
                    t[-pad:] = SENTINEL
            part = census_tiles_pallas(
                jnp.asarray(uu), jnp.asarray(vv), g.n,
                *(jnp.asarray(tiles[k]) for k in
                  ("out_u", "in_u", "out_v", "in_v", "nbr_u", "nbr_v")),
                block=block, interpret=interpret)
            counts += np.asarray(part, dtype=np.int64)
            plan.stats["chunks"] += 1
    return counts
