"""Compiled multi-analytic plans + the plan cache (the serving hot path).

``compile(graph_meta, ops, config) -> Plan`` is the engine's front door: a
:class:`Plan` owns everything the historical paths re-derived per call —
canonical-dyad enumeration, padding, tile building, degree bucketing, task
sharding, the scan/partial-histogram schedule — and executes **any number
of** :class:`~repro.engine.ops.GraphOp` analytics **in one fused pass**
over the streaming dyad pipeline: one traversal, one on-device hi/lo
accumulator (each op owns a slice), one device→host transfer, per-op
results.  Two properties carry over from the census-only engine:

  * a **plan cache** keyed on static graph metadata buckets (n, max-degree
    and arc counts rounded to powers of two) + op names + config, so
    repeated analytics on same-shape graphs reuse one compiled plan and
    hit zero retraces (bounded LRU — see :func:`set_plan_cache_capacity`),
  * **chunked streaming execution**: the compiled unit processes a
    fixed-shape chunk of dyads, so its trace is independent of the dyad
    count and graphs whose full dyad tiles exceed device memory still run.

``compile_census`` / :class:`CensusPlan` are the original census-only API,
now thin views over the same plans: a census wrapper and a new-API plan
for the same (bucket, config, ops) share ONE cache entry and one set of
compiled units — no double compiles.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import math
import weakref
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.census import CensusResult
from ..core.graph import CSRGraph, GraphArrays
from ..core.graph import next_pow2 as _next_pow2
from ..core.reorder import compute_permutation, permute_graph
from . import backends
from .config import EngineConfig
from .executor import Executor
from .faults import InjectedFault, check_poisoned, resolve_faults
from .ops import OpLayout, resolve_ops

__all__ = ["CensusPlan", "GraphMeta", "Plan", "PlanShapeError", "compile",
           "compile_census", "clear_plan_cache", "plan_cache_stats",
           "set_plan_cache_capacity"]


class PlanShapeError(ValueError):
    """A graph exceeds the plan's metadata buckets (tile width or array
    bounds) — recompile via :func:`repro.engine.compile` at the graph's
    own shape.  Subclasses ``ValueError`` so pre-existing handlers keep
    working; exists as its own type so stateful callers (the serve
    layer's subscribed sessions) can tell "this graph outgrew its plan,
    recompile" apart from genuinely invalid input."""


@dataclasses.dataclass(frozen=True)
class GraphMeta:
    """Static, bucketized graph shape — the graph half of the plan-cache
    key.

    All fields are rounded up to powers of two so graphs of similar shape
    map to the same plan (and therefore the same compiled trace).
    """

    n_bucket: int       # vertices, rounded up
    k: int              # candidate tile width (>= max undirected degree)
    member_iters: int   # binary-search trips covering any CSR row
    m_out_bucket: int   # directed-arc array length, rounded up
    m_nbr_bucket: int   # undirected-adjacency array length, rounded up

    @classmethod
    def from_graph(cls, g: CSRGraph, k: Optional[int] = None) -> "GraphMeta":
        k_bucket = _next_pow2(max(g.max_deg, 1))
        k_eff = int(k) if k else k_bucket
        # membership searches run over REAL rows, so iteration count must
        # cover the true max degree even under a (dryrun) K override.
        depth = max(k_eff, k_bucket)
        iters = max(1, math.ceil(math.log2(depth + 1))) + 1
        return cls(
            n_bucket=_next_pow2(max(g.n, 1)),
            k=k_eff,
            member_iters=iters,
            m_out_bucket=_next_pow2(max(g.m, 1)),
            m_nbr_bucket=_next_pow2(max(g.m_nbr, 1)),
        )


def _pad_to(a: np.ndarray, size: int, fill) -> np.ndarray:
    out = np.full(size, fill, dtype=a.dtype)
    out[: len(a)] = a
    return out


class Plan:
    """A compiled, reusable fused-analytic execution plan.

    Create via :func:`compile`; run with :meth:`run` (returns ``{op_name:
    result}``).  One plan serves every graph whose :class:`GraphMeta`
    matches — arrays are padded to the metadata buckets before entering
    the device, so no input shape (and hence no trace) depends on the
    concrete graph.  However many ops the plan carries, execution is one
    traversal of the dyad stream and one device→host transfer
    (``stats["host_syncs"]`` is identical to a single-op run).
    """

    def __init__(self, meta: GraphMeta, ops, config: EngineConfig,
                 backend: str, mesh=None):
        self.meta = meta
        self.ops = tuple(ops)
        self.op_names = tuple(op.name for op in self.ops)
        self.config = config
        self.backend = backend
        self.mesh = mesh
        self.layout = OpLayout(self.ops, meta, config)
        # streaming chunk, capped by the graph's dyad-count bucket
        # (m_nbr_bucket/2 >= n_dyads) so small graphs don't pad to a full
        # default chunk; both terms are static, so shapes stay cache-stable.
        batch = config.batch
        dyad_cap = -(-max(1, meta.m_nbr_bucket // 2) // batch) * batch
        self.chunk = min(config.resolve_chunk(), dyad_cap)
        # device-resident dyad list length: the dyad-count bucket rounded up
        # to whole chunks, so every chunk's dynamic_slice stays in bounds
        # (and the shape stays a pure function of the metadata buckets).
        d_bucket = max(1, meta.m_nbr_bucket // 2)
        self.dyad_pad = max(self.chunk, -(-d_bucket // self.chunk) * self.chunk)
        self.device_path = config.resolve_device_accum()
        # partitioned-graph subsystem: shard count (1 = unpartitioned) and
        # the locality precondition — every op's per-dyad contribution must
        # read only {u, v} ∪ N(u) ∪ N(v) (the delta_local contract), which
        # is exactly what each shard's halo keeps locally.
        self.partitions = config.resolve_partitions()
        self.partition_mode = config.resolve_partition_mode(backend)
        if self.partitions > 1:
            nonlocal_ops = [op.name for op in self.ops
                            if not getattr(op, "delta_local", True)]
            if nonlocal_ops:
                raise ValueError(
                    f"partitions={self.partitions} requires every op to "
                    f"honor the delta_local locality contract, but "
                    f"{nonlocal_ops} opt out — their kernels may read "
                    "rows outside a shard's halo; run them unpartitioned "
                    "(partitions=1)")
            if self.partition_mode == "mesh" and backend != "distributed":
                raise ValueError(
                    f"partition_mode='mesh' requires the distributed "
                    f"backend (got backend={backend!r}): the mesh mode "
                    "stacks shard contexts along a shard_map mesh axis — "
                    "use partition_mode='pool' (concurrent pool devices) "
                    "or 'serial' on this backend")
            if self.partition_mode == "pool" and backend == "distributed":
                raise ValueError(
                    "partition_mode='pool' is not available on the "
                    "distributed backend: its mesh already owns every "
                    "device (the executor pool is pinned to one slot) — "
                    "use partition_mode='mesh' (the default there) or "
                    "'serial'")
        self.stats = {"traces": 0, "runs": 0, "chunks": 0, "host_syncs": 0,
                      "batch_runs": 0, "batch_graphs": 0, "device_chunks": {},
                      "delta_runs": 0, "delta_fulls": 0, "reorders": 0,
                      "faults": dict(chunk_failures=0, retries=0,
                                     device_losses=0, quarantines=0,
                                     backend_fallbacks=0,
                                     schedule_fallbacks=0),
                      "fault_events": []}
        # the degradation ladder: `backend` is the rung currently
        # executing, `requested_backend` the compile-time ask, and
        # `degradation` the (usually empty) record of every demotion —
        # surfaced per cache entry by plan_cache_stats().
        self.requested_backend = backend
        self.degradation: list = []
        # chunk dispatch policy + device pool (static 1-slot by default;
        # the distributed backend's mesh already owns every device, so its
        # pool is always pinned to one slot).
        self.executor = Executor(
            config, self.stats,
            n_devices=(1 if backend == "distributed"
                       else config.resolve_executor_devices()),
            backend=backend)
        self._batch_fn = None  # lazily-built vmapped unit (xla device path)
        self._census_view = None  # memoized CensusPlan compat wrapper
        # bounded per-graph memo of host-derived chunk schedules
        # (see repro.engine.backends._memo_tasks)
        self._task_memo: dict = {}
        # bounded per-graph memo of reorder permutations + relabeled
        # graphs (config.reorder != "none"): warm runs pay zero reorder
        # cost.  Same lifetime/bound discipline as _task_memo.
        self._reorder_memo: dict = {}
        # bounded per-graph memo of partition layouts (metadata only —
        # cuts, halo ids, shard sizes; local CSRs rebuild per run).  Same
        # lifetime/bound discipline as the memos above.
        self._partition_memo: dict = {}
        # lazily-built shard_map unit for partition_mode="mesh" (one per
        # plan; jit retraces per shard-geometry bucket like every unit).
        self._mesh_part_fn = None
        # distributed: per-shard load summary of the most recent run
        # (a backends.TaskStats — plans are cached with a bounded LRU, so
        # only the (n_shards,) weights are retained, never the task arrays).
        self.last_task_stats = None
        fplan = resolve_faults(config.fault_plan)
        try:
            if fplan is not None and fplan.compile_fails(backend):
                raise InjectedFault(f"injected {backend} compile failure")
            self._fn = self._build_fn(backend)
        except Exception as e:
            # pallas→xla is the only compile-fallback rung: the xla unit
            # runs the same fused layout anywhere, while a distributed
            # mesh failure or an unknown backend has no safe substitute.
            if backend != "pallas" or not config.backend_fallback:
                raise
            self._demote("xla", stage="compile", reason=repr(e))

    def _build_fn(self, backend: str):
        """Build ``backend``'s compiled chunk/stream unit (the ladder
        re-enters this when demoting pallas→xla)."""
        config = self.config
        if backend == "xla":
            return (
                backends.make_xla_stream_fn(self.layout, config, self.stats,
                                            self.chunk)
                if self.device_path
                else backends.make_xla_chunk_fn(self.layout, config,
                                                self.stats))
        if backend == "distributed":
            if self.mesh is None:
                raise ValueError("distributed backend needs a mesh")
            make = (backends.make_distributed_stream_fn if self.device_path
                    else backends.make_distributed_chunk_fn)
            return make(self.layout, config, self.mesh, self.stats)
        if backend == "pallas":
            # fused chunk unit; pallas_call manages its own per-shape cache
            return backends.make_pallas_chunk_fn(self.layout, config)
        raise ValueError(f"unknown backend {backend!r}")

    def _demote(self, to: str, *, stage: str, reason: str) -> None:
        """One rung of the degradation ladder: permanently re-point this
        plan at backend ``to`` (rebuilding its compiled unit), record the
        event in ``degradation`` / ``stats``, and keep serving.  The xla
        unit computes the same fused integer bins, so demoted results
        stay bit-identical; chunk-schedule memo entries are keyed by
        backend kind and cannot leak across the demotion."""
        frm = self.backend
        self.backend = to
        self.executor.backend = to
        self._fn = self._build_fn(to)
        self._batch_fn = None
        self.stats["faults"]["backend_fallbacks"] += 1
        trace = self.stats["fault_events"]
        if len(trace) < 512:
            trace.append(("backend_fallback", frm, to, stage))
        self.degradation.append(dict(rung=f"{frm}->{to}", stage=stage,
                                     reason=reason))

    # -- graph admission -----------------------------------------------------

    def _check(self, g: CSRGraph):
        m = self.meta
        if g.max_deg > m.k:
            raise PlanShapeError(
                f"graph max_deg={g.max_deg} exceeds plan tile width k={m.k}; "
                f"recompile via repro.engine.compile(graph, ops, config)")
        if g.n > m.n_bucket or g.m > m.m_out_bucket or g.m_nbr > m.m_nbr_bucket:
            raise PlanShapeError(
                f"graph (n={g.n}, m={g.m}, m_nbr={g.m_nbr}) exceeds plan "
                f"buckets {m}; recompile via repro.engine.compile(graph, "
                f"ops, config)")

    def padded_arrays_host(self, g: CSRGraph) -> GraphArrays:
        """Bucket-padded arrays as host numpy (no device transfer).

        The batched path (:func:`repro.engine.backends.run_xla_batch`)
        pads + stacks a whole batch on host and ships **one** device put
        per field — per-graph puts would otherwise dominate small-graph
        fleet serving.  Padding semantics match :meth:`padded_arrays`.
        """
        m = self.meta
        a = g.arrays
        out_ptr = np.asarray(a.out_ptr)
        nbr_ptr = np.asarray(a.nbr_ptr)
        return GraphArrays(
            out_ptr=_pad_to(out_ptr, m.n_bucket + 1, out_ptr[-1]),
            out_idx=_pad_to(np.asarray(a.out_idx), m.m_out_bucket, 0),
            nbr_ptr=_pad_to(nbr_ptr, m.n_bucket + 1, nbr_ptr[-1]),
            nbr_idx=_pad_to(np.asarray(a.nbr_idx), m.m_nbr_bucket, 0),
            nbr_deg=_pad_to(np.asarray(a.nbr_deg), m.n_bucket, 0),
        )

    def padded_arrays(self, g: CSRGraph, *,
                      with_in_csr: Optional[bool] = None) -> GraphArrays:
        """Device arrays padded to the metadata buckets (shape-stable).

        Padded ptr rows repeat the last offset (empty rows: binary search
        sees lo == hi and never matches); padded idx/deg entries are inert.

        ``with_in_csr`` additionally populates the transpose (in-arc) CSR
        fields, built **on device** by
        :func:`repro.kernels.ops.build_in_csr_device` — once per run, no
        host round trip.  Default: only for the device-resident pallas
        path when an op actually uses the census tile kernel, the one
        consumer of in-arc tiles.
        """
        host = self.padded_arrays_host(g)
        arrays = GraphArrays(
            **{f: (None if v is None else jnp.asarray(v))
               for f, v in zip(GraphArrays._fields, host)})
        if with_in_csr is None:
            with_in_csr = (self.backend == "pallas" and self.device_path
                           and "triad_census" in self.layout.slices)
        if with_in_csr:
            from ..kernels import ops
            in_ptr, in_idx = ops.build_in_csr_device(arrays.out_ptr,
                                                     arrays.out_idx)
            arrays = arrays._replace(in_ptr=in_ptr, in_idx=in_idx)
        return arrays

    # -- locality-aware reordering -------------------------------------------

    def _seed_reorder(self, g: CSRGraph, g_exec: CSRGraph,
                      perm: np.ndarray) -> None:
        """Record ``(g -> (g_exec, perm))`` in the bounded reorder memo.

        Keyed by graph identity with a weakref guard against id reuse
        (the ``_memo_tasks`` discipline); bounded to 8 live graphs per
        plan — mutation streams touch one or two.  The delta path seeds
        the mutated graph's entry so a session's every step reuses ONE
        permutation and stays warm.
        """
        memo = self._reorder_memo
        while len(memo) >= 8:
            memo.pop(next(iter(memo)))
        memo[id(g)] = (weakref.ref(g), g_exec, perm)

    def _reordered(self, g: CSRGraph):
        """``(execution graph, perm)`` for this plan's ``reorder=`` policy.

        ``("none")`` returns ``(g, None)`` — the zero-cost identity.
        Otherwise the permutation (``perm[old_id] = new_id``, see
        :mod:`repro.core.reorder`) is computed host-side ONCE per (plan,
        graph) and memoized together with the relabeled graph; warm runs
        pay nothing (``stats["reorders"]`` counts the cold computations).
        Relabeling preserves every metadata bucket, so the execution
        graph passes the same admission check the original did.
        """
        if self.config.reorder == "none":
            return g, None
        hit = self._reorder_memo.get(id(g))
        if hit is not None and hit[0]() is g:
            return hit[1], hit[2]
        perm = compute_permutation(g, self.config.reorder)
        g_exec = permute_graph(g, perm)
        self.stats["reorders"] += 1
        self._seed_reorder(g, g_exec, perm)
        return g_exec, perm

    def _execute_raw(self, g: CSRGraph) -> np.ndarray:
        """Reorder-aware raw execution: relabel (memoized), dispatch the
        backend on the execution graph, and map raw bins back through the
        inverse permutation (identity for aggregate ops — see
        ``GraphOp.unpermute_raw``), so the raw contract is always
        ORIGINAL vertex space regardless of ``config.reorder``."""
        g_exec, perm = self._reordered(g)
        raw = self._run_raw(g_exec)
        return raw if perm is None else self.layout.unpermute(raw, perm, g)

    # -- execution -----------------------------------------------------------

    def run(self, g: CSRGraph) -> dict:
        """Execute every op in one fused pass; returns ``{op_name: result}``.

        One traversal of the dyad stream, one on-device accumulator, one
        device→host sync — the same schedule a single-op plan runs.
        Semantically the ``B = 1`` case of :meth:`run_batch`; it executes
        through the single-graph (un-vmapped) units, which produce
        bit-identical raw bins — every op is pure integer arithmetic.
        """
        return self.layout.finalize(self.run_raw(g), g)

    def run_raw(self, g: CSRGraph) -> np.ndarray:
        """Execute the fused pass and return the raw int64 accumulator bins
        (no per-op finalize).  This is the state a delta-census stream
        carries between mutations: seed a session with ``raw =
        plan.run_raw(g)``, then advance it with :meth:`apply_delta` —
        ``layout.finalize(raw, g)`` recovers the per-op results at any
        point.  Counts as one run (same stats/sync accounting as
        :meth:`run`).  Raw bins are always in ORIGINAL vertex space: under
        ``config.reorder`` the backend runs on the relabeled graph and the
        bins map back through the inverse permutation before returning."""
        check_poisoned(g)
        self._check(g)
        self.stats["runs"] += 1
        return self._execute_raw(g)

    def apply_delta(self, g: CSRGraph, delta, raw=None) -> "DeltaResult":
        """Advance a census stream by one mutation batch — work
        proportional to the delta's footprint, not the graph.

        ``g`` is the current graph and ``raw`` its raw bins (from
        :meth:`run_raw` or the previous application's ``.raw``); ``delta``
        is a :class:`~repro.core.delta.GraphDelta`.  Returns a
        :class:`~repro.engine.delta.DeltaResult` whose ``graph`` / ``raw``
        seed the next application and whose ``results`` are bit-identical
        to ``plan.run(result.graph)`` — the correction pass re-runs the
        plan's own chunk machinery on the affected dyads of both graphs
        and folds the exact integer difference (module
        :mod:`repro.engine.delta`), costing ONE counted device→host sync.
        Falls back to a full recompute (``mode == "full"``) when ``raw``
        is ``None``, the affected fraction exceeds
        ``config.delta_threshold``, the plan runs the synchronous
        baseline, or an op opts out via ``delta_local=False``.  Raises
        :class:`PlanShapeError` if the mutated graph outgrows the plan's
        buckets — recompile at the new shape and rerun.
        """
        from .delta import run_delta
        self._check(g)
        self.stats["runs"] += 1
        return run_delta(self, g, delta, raw)

    def _run_raw(self, g: CSRGraph) -> np.ndarray:
        """Backend dispatch: the fused raw int64 bins (no finalize).

        The pallas→xla runtime rung of the degradation ladder lives
        here: a pallas run that fails (after the executor's own bounded
        retries) demotes the plan and re-runs on xla — bit-identical
        bins, one extra counted sync for the failed run only, and every
        later run executes on the demoted rung directly.

        ``partitions > 1`` dispatches the sharded-CSR path instead
        (:func:`repro.engine.partition.run_partitioned`) — inside the
        same try, so the ladder composes: a failed pallas shard pass
        demotes the plan and the whole partitioned run re-enters on
        xla.  Reordering composes upstream (``_execute_raw`` relabels
        before dispatch), so partition cuts are computed over the
        locality-relabeled ids — PR 8's reorder doubles as the
        partitioner."""
        try:
            if self.partitions > 1:
                from .partition import run_partitioned
                return run_partitioned(self, g)
            return backends.RUNNERS[self.backend](self, g)
        except Exception as e:
            if self.backend != "pallas" or not self.config.backend_fallback:
                raise
            self._demote("xla", stage="runtime", reason=repr(e))
            return self._run_raw(g)

    def run_batch(self, graphs) -> "list[dict]":
        """Execute the fused pass on B same-bucket graphs as one batch.

        Every graph must pass this plan's admission check (same metadata
        buckets — the :class:`GraphMeta` grouping a
        :class:`repro.serve.CensusService` performs).  On the xla
        device-resident path the whole batch runs through one vmapped
        fixed-shape unit — a leading batch axis over the padded graph
        arrays, the device dyad lists and the fused hi/lo accumulator —
        so B requests cost one chunk schedule of dispatches and **one**
        device→host transfer instead of B of each.  Results are
        bit-identical to B sequential :meth:`run` calls (integer
        arithmetic; excess chunks for shorter graphs are masked no-ops).

        The pallas / distributed backends and the synchronous baseline
        (``device_accum=False``) have no vmapped unit yet; there the batch
        executes member-wise through the single-graph path — same results,
        amortizing only the plan, not the dispatch.

        Returns one ``{op_name: result}`` dict per graph, in input order.
        """
        graphs = list(graphs)
        if not graphs:
            return []
        for g in graphs:
            # a poisoned member fails the batch as a unit — the serve
            # layer's member-wise retry is what isolates it from peers.
            check_poisoned(g)
            self._check(g)
        self.stats["runs"] += len(graphs)
        self.stats["batch_runs"] += 1
        self.stats["batch_graphs"] += len(graphs)
        if self.backend == "xla" and self.device_path and self.partitions == 1:
            # reorder each member (memoized) and batch the relabeled
            # graphs — same buckets, so the vmapped unit is unchanged;
            # raw bins map back per member before finalize.  Partitioned
            # plans take the member-wise branch below: each member runs
            # the sharded path with its own bounded shard contexts.
            pairs = [self._reordered(g) for g in graphs]
            raws = backends.run_xla_batch(self, [ge for ge, _ in pairs])
            return [self.layout.finalize(
                        raw if perm is None
                        else self.layout.unpermute(raw, perm, g), g)
                    for raw, (_, perm), g in zip(raws, pairs, graphs)]
        return [self.layout.finalize(self._execute_raw(g), g) for g in graphs]

    def batch_fn(self):
        """The vmapped batched unit (xla device path), built lazily.

        One jitted callable serves every batch size — jit retraces per
        distinct (power-of-two-padded) B, counted in ``stats['traces']``.
        """
        if self._batch_fn is None:
            self._batch_fn = backends.make_xla_stream_batch_fn(
                self.layout, self.config, self.stats, self.chunk)
        return self._batch_fn

    def aot_lower(self, g: CSRGraph):
        """Lower the compiled chunk unit at this plan's static shapes.

        For dry-run/roofline analysis (memory_analysis, cost_analysis)
        without executing.  Only xla/distributed expose a jitted unit.
        """
        if self.backend == "pallas":
            raise NotImplementedError("pallas backend has no jitted unit")
        m = self.meta
        arrays = GraphArrays(
            out_ptr=jax.ShapeDtypeStruct((m.n_bucket + 1,), jnp.int32),
            out_idx=jax.ShapeDtypeStruct((m.m_out_bucket,), jnp.int32),
            nbr_ptr=jax.ShapeDtypeStruct((m.n_bucket + 1,), jnp.int32),
            nbr_idx=jax.ShapeDtypeStruct((m.m_nbr_bucket,), jnp.int32),
            nbr_deg=jax.ShapeDtypeStruct((m.n_bucket,), jnp.int32),
        )
        n = jax.ShapeDtypeStruct((), jnp.int32)
        if self.backend == "distributed":
            n_dev = math.prod(self.mesh.devices.shape)
            shape = (n_dev, backends.chunk_l(self))
        else:
            shape = (self.chunk,)
        ints = jax.ShapeDtypeStruct(shape, jnp.int32)
        bools = jax.ShapeDtypeStruct(shape, jnp.bool_)
        if not self.device_path:
            return self._fn.lower(arrays, n, ints, ints, bools)
        scalar = jax.ShapeDtypeStruct((), jnp.int32)
        acc = jax.ShapeDtypeStruct((self.layout.total_bins,), jnp.int32)
        if self.backend == "distributed":
            return self._fn.lower(arrays, n, ints, ints, bools, acc, acc)
        dyads = jax.ShapeDtypeStruct((self.dyad_pad,), jnp.int32)
        return self._fn.lower(arrays, n, dyads, dyads, scalar, scalar,
                              acc, acc)

    # -- compat --------------------------------------------------------------

    def census_view(self) -> "CensusPlan":
        """The census-only compat view over this plan (memoized — repeat
        calls return the identical :class:`CensusPlan` object, which is
        what keeps ``compile_census``'s is-identity cache semantics)."""
        if "triad_census" not in self.op_names:
            raise ValueError(f"plan ops {self.op_names} do not include "
                             "'triad_census'")
        if self._census_view is None:
            self._census_view = CensusPlan(self)
        return self._census_view


class CensusPlan:
    """Triad-census view of a generalized :class:`Plan` (the original
    census-only API, unchanged for callers).

    Created by ``compile_census``; every attribute (``stats``, ``meta``,
    ``config``, ``chunk``, ``device_path``, ...) delegates to the
    underlying multi-op plan — the SAME cached object a new-API
    ``compile(graph, ("triad_census",), config)`` returns — and
    :meth:`run` / :meth:`run_batch` unwrap the fused result dict to bare
    :class:`~repro.core.census.CensusResult` values, bit-identical to the
    pre-GraphOp engine.
    """

    def __init__(self, plan: Plan):
        self._plan = plan

    def __getattr__(self, name):
        return getattr(self._plan, name)

    def run(self, g: CSRGraph) -> CensusResult:
        """Execute the census; returns int64 counts for all 16 triad types
        (including the type-003 closed form).  Semantically the ``B = 1``
        case of :meth:`run_batch` — see :meth:`Plan.run`.
        """
        return self._plan.run(g)["triad_census"]

    def run_batch(self, graphs) -> "list[CensusResult]":
        """Execute the census on B same-bucket graphs as one batch.

        The census-only unwrapping of :meth:`Plan.run_batch` (see there
        for batching semantics): one vmapped dispatch schedule and one
        device→host transfer on the xla device path, member-wise fallback
        elsewhere, results bit-identical to sequential :meth:`run` calls.
        Returns one :class:`~repro.core.census.CensusResult` per graph,
        in input order.
        """
        return [r["triad_census"] for r in self._plan.run_batch(graphs)]

    def padded_arrays(self, g: CSRGraph, *,
                      with_in_csr: Optional[bool] = None) -> GraphArrays:
        """Device arrays padded to the metadata buckets (shape-stable);
        see :meth:`Plan.padded_arrays` for padding + transpose-CSR
        semantics."""
        return self._plan.padded_arrays(g, with_in_csr=with_in_csr)

    def padded_arrays_host(self, g: CSRGraph) -> GraphArrays:
        """Bucket-padded arrays as host numpy (no device transfer); see
        :meth:`Plan.padded_arrays_host` for why the batched path wants
        host-side padding."""
        return self._plan.padded_arrays_host(g)

    def aot_lower(self, g: CSRGraph):
        """Lower the compiled chunk unit at this plan's static shapes for
        dry-run/roofline analysis; see :meth:`Plan.aot_lower`."""
        return self._plan.aot_lower(g)

    def batch_fn(self):
        """The vmapped batched unit (xla device path), built lazily on the
        underlying plan; see :meth:`Plan.batch_fn`."""
        return self._plan.batch_fn()


# ----------------------------------------------------------------------------
# plan cache (bounded LRU)
# ----------------------------------------------------------------------------

_PLAN_CACHE: collections.OrderedDict = collections.OrderedDict()
_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}
_DEFAULT_CAPACITY = 32
_CACHE_CAPACITY = _DEFAULT_CAPACITY


def set_plan_cache_capacity(capacity: int) -> None:
    """Bound the plan cache to ``capacity`` entries (LRU eviction).

    Long-lived multi-graph services would otherwise accumulate one
    compiled plan (and its XLA executable) per distinct metadata bucket
    forever.  Shrinking the capacity evicts the least-recently-used plans
    immediately; evictions are counted in :func:`plan_cache_stats`.
    """
    global _CACHE_CAPACITY
    if capacity < 1:
        raise ValueError("plan cache capacity must be >= 1")
    _CACHE_CAPACITY = capacity
    _evict_to_capacity()


def _evict_to_capacity() -> None:
    while len(_PLAN_CACHE) > _CACHE_CAPACITY:
        _PLAN_CACHE.popitem(last=False)
        _CACHE_STATS["evictions"] += 1


@functools.lru_cache(maxsize=8)
def _default_mesh(n_dev: int):
    return jax.make_mesh((n_dev,), ("data",))


def compile(graph_meta, ops=("triad_census",),
            config: Optional[EngineConfig] = None, *, mesh=None) -> Plan:
    """Build (or fetch from cache) the fused plan for this graph shape +
    op set.

    ``graph_meta`` is a :class:`CSRGraph` (metadata extracted and
    bucketized) or an explicit :class:`GraphMeta`.  ``ops`` is a GraphOp
    name, a :class:`~repro.engine.ops.GraphOp` instance, or a sequence of
    either (see :func:`repro.engine.ops.list_ops`); order fixes the
    result-dict order.  Plans are cached on (metadata buckets, op names,
    config, resolved backend, mesh): a second compile for a same-shape
    graph returns the identical plan object and re-uses its compiled
    trace — and a census-only ``compile_census`` call shares the same
    entry as ``compile(graph, ("triad_census",), config)``.
    """
    config = config or EngineConfig()
    op_objs = resolve_ops(ops)
    meta = (graph_meta if isinstance(graph_meta, GraphMeta)
            else GraphMeta.from_graph(graph_meta, k=config.k))
    backend = config.resolve_backend()
    # normalize: an "auto" config and the explicit backend it resolves to
    # must share one cache entry (and one compiled plan); likewise
    # device_accum=None and the True it resolves to, and the executor
    # pool width None/over-asked resolves to (1 under the static schedule
    # and on the distributed backend, whose mesh owns every device).
    config = dataclasses.replace(
        config, backend=backend,
        device_accum=config.resolve_device_accum(),
        n_executor_devices=(1 if backend == "distributed"
                            else config.resolve_executor_devices()),
        partitions=config.resolve_partitions(),
        spill=config.resolve_spill(),
        partition_mode=config.resolve_partition_mode(backend))
    if backend == "distributed" and mesh is None:
        mesh = _default_mesh(len(jax.devices()))
    # key on the op *instances* (identity), not their names: re-registering
    # an op (overwrite=True) or passing an unregistered instance whose name
    # collides with a built-in must compile fresh, never reuse a plan built
    # against a different implementation.
    key = (meta, op_objs, config, mesh)
    plan = _PLAN_CACHE.get(key)
    if plan is not None:
        _CACHE_STATS["hits"] += 1
        _PLAN_CACHE.move_to_end(key)  # LRU freshness
        return plan
    _CACHE_STATS["misses"] += 1
    plan = Plan(meta, op_objs, config, backend, mesh)
    _PLAN_CACHE[key] = plan
    _evict_to_capacity()
    return plan


def compile_census(graph_meta, config: Optional[EngineConfig] = None, *,
                   mesh=None) -> CensusPlan:
    """Build (or fetch from cache) the census plan for this graph shape.

    The original front door, now a thin wrapper: delegates to
    ``compile(graph_meta, ("triad_census",), config)`` — so census-only
    wrapper plans and new-API plans for the same (bucket, config, ops)
    share ONE cache entry and compile once — and returns the plan's
    memoized census view (repeat calls on a warm cache return the
    identical :class:`CensusPlan` object).
    """
    return compile(graph_meta, ("triad_census",), config,
                   mesh=mesh).census_view()


def clear_plan_cache() -> None:
    """Drop every cached plan and reset hit/miss/eviction counters.

    Compiled XLA executables owned by the dropped plans become garbage;
    use in tests/benchmarks to force cold compiles.  Each plan's
    per-graph chunk-schedule memo (``_task_memo`` — the host-derived
    pallas bucket schedules and cost-model boundaries) is cleared too,
    as is its reorder memo (``_reorder_memo`` — the per-graph locality
    permutations and relabeled graphs): both memos' lifetimes are tied to
    the plan cache, so long-lived mutation streams can drop every
    host-side schedule and permutation with one call.
    """
    for p in _PLAN_CACHE.values():
        p._task_memo.clear()
        p._reorder_memo.clear()
        p._partition_memo.clear()
    _PLAN_CACHE.clear()
    _CACHE_STATS.update(hits=0, misses=0, evictions=0)


def plan_cache_stats() -> dict:
    """Plan-cache counters plus per-entry (per-bucket) metadata.

    Returns ``hits`` / ``misses`` / ``evictions`` / ``size`` /
    ``capacity`` plus ``entries``: one dict per cached plan, in LRU order
    (oldest first), holding the bucketized ``meta`` fields, ``backend``
    (the rung currently executing) with ``requested_backend`` and the
    ``degradation`` event list (the ladder's per-plan record, normally
    empty), ``device_path``, the plan's ``ops`` (op-name tuple), the resolved
    streaming ``chunk``, the executor policy (``schedule`` and
    ``n_devices`` — the resolved pool width), and the plan's live
    execution counters (``runs``, ``batch_runs``, ``batch_graphs``,
    ``traces``, ``chunks``, ``host_syncs``, ``delta_runs`` /
    ``delta_fulls`` — incremental applications split by path — plus
    ``faults`` / ``fault_events``: the executor's recovery counters and
    bounded event trace (retries, quarantines, device losses,
    fallbacks), ``device_chunks``: chunks dispatched per executor pool
    device, and
    ``task_memo``: live entries in the plan's bounded per-graph
    chunk-schedule memo, cleared with the cache by
    :func:`clear_plan_cache`, and the locality policy — ``reorder``
    (the plan's relabeling strategy) with ``reorder_memo``, the live
    entries in its bounded per-graph permutation memo).  Partitioned
    plans additionally report ``partitions`` (the configured shard
    count; 1 = unpartitioned), ``partition_mode`` (the resolved shard
    residency policy — ``"pool"`` / ``"serial"`` / ``"mesh"``, ``None``
    unpartitioned), ``partition_memo`` (live layout-memo
    entries) and — after a partitioned run — ``partition``, the last
    run's layout record (cuts, per-shard dyad counts, halo sizes, spill
    staging footprint, plus the residency observables: ``h2d_puts``
    (counted host→device shard stagings), ``d2d_puts`` (device-side halo
    peer transfers), ``shard_overlap`` (fraction of busy wall time with
    two or more shards in flight) and ``shard_times`` (per-shard
    start/end/tasks/device records); see
    :mod:`repro.engine.partition`).  This is the introspection surface
    :class:`repro.serve.CensusService` reports per-bucket stats from.
    """
    entries = [
        dict(meta=dataclasses.asdict(p.meta), backend=p.backend,
             requested_backend=p.requested_backend,
             degradation=[dict(d) for d in p.degradation],
             device_path=p.device_path, chunk=p.chunk, ops=p.op_names,
             schedule=p.config.schedule, n_devices=p.executor.n_devices,
             task_memo=len(p._task_memo), reorder=p.config.reorder,
             reorder_memo=len(p._reorder_memo),
             partitions=p.partitions,
             partition_mode=p.partition_mode,
             partition_memo=len(p._partition_memo),
             **{**p.stats,
                "device_chunks": dict(p.stats["device_chunks"]),
                "faults": dict(p.stats["faults"]),
                "fault_events": list(p.stats["fault_events"]),
                **({"partition": dict(p.stats["partition"])}
                   if "partition" in p.stats else {})})
        for p in _PLAN_CACHE.values()
    ]
    return {**_CACHE_STATS, "size": len(_PLAN_CACHE),
            "capacity": _CACHE_CAPACITY, "entries": entries}
