"""Compiled census plans + the plan cache (the serving hot path).

``compile_census(graph_meta, config) -> CensusPlan`` is the single public
entry point for the Triad Census.  A :class:`CensusPlan` owns everything the
three historical paths each re-derived per call — canonical-dyad
enumeration, padding, tile building, degree bucketing, task sharding, the
scan/partial-histogram schedule, and the host-side int64 merge with the
type-003 closed form — plus two things none of them had:

  * a **plan cache** keyed on static graph metadata buckets (n, max-degree
    and arc counts rounded to powers of two) + the config, so repeated
    censuses on same-shape graphs reuse one compiled plan and hit zero
    retraces (bounded LRU — see :func:`set_plan_cache_capacity`), and
  * **chunked streaming execution**: the compiled unit processes a
    fixed-shape chunk of dyads, so its trace is independent of the dyad
    count and graphs whose full dyad tiles exceed device memory still run.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.census import CensusResult
from ..core.graph import CSRGraph, GraphArrays
from ..core.graph import next_pow2 as _next_pow2
from . import backends
from .config import CensusConfig

__all__ = ["GraphMeta", "CensusPlan", "compile_census", "clear_plan_cache",
           "plan_cache_stats", "set_plan_cache_capacity"]


def _c3(n: int) -> int:
    return n * (n - 1) * (n - 2) // 6 if n >= 3 else 0


@dataclasses.dataclass(frozen=True)
class GraphMeta:
    """Static, bucketized graph shape — one half of the plan-cache key.

    All fields are rounded up to powers of two so graphs of similar shape
    map to the same plan (and therefore the same compiled trace).
    """

    n_bucket: int       # vertices, rounded up
    k: int              # candidate tile width (>= max undirected degree)
    member_iters: int   # binary-search trips covering any CSR row
    m_out_bucket: int   # directed-arc array length, rounded up
    m_nbr_bucket: int   # undirected-adjacency array length, rounded up

    @classmethod
    def from_graph(cls, g: CSRGraph, k: Optional[int] = None) -> "GraphMeta":
        k_bucket = _next_pow2(max(g.max_deg, 1))
        k_eff = int(k) if k else k_bucket
        # membership searches run over REAL rows, so iteration count must
        # cover the true max degree even under a (dryrun) K override.
        depth = max(k_eff, k_bucket)
        iters = max(1, math.ceil(math.log2(depth + 1))) + 1
        return cls(
            n_bucket=_next_pow2(max(g.n, 1)),
            k=k_eff,
            member_iters=iters,
            m_out_bucket=_next_pow2(max(g.m, 1)),
            m_nbr_bucket=_next_pow2(max(g.m_nbr, 1)),
        )


def _pad_to(a: np.ndarray, size: int, fill) -> np.ndarray:
    out = np.full(size, fill, dtype=a.dtype)
    out[: len(a)] = a
    return out


class CensusPlan:
    """A compiled, reusable census execution plan.

    Create via :func:`compile_census`; run with :meth:`run`.  One plan
    serves every graph whose :class:`GraphMeta` matches — arrays are padded
    to the metadata buckets before entering the device, so no input shape
    (and hence no trace) depends on the concrete graph.
    """

    def __init__(self, meta: GraphMeta, config: CensusConfig, backend: str,
                 mesh=None):
        self.meta = meta
        self.config = config
        self.backend = backend
        self.mesh = mesh
        # streaming chunk, capped by the graph's dyad-count bucket
        # (m_nbr_bucket/2 >= n_dyads) so small graphs don't pad to a full
        # default chunk; both terms are static, so shapes stay cache-stable.
        batch = config.batch
        dyad_cap = -(-max(1, meta.m_nbr_bucket // 2) // batch) * batch
        self.chunk = min(config.resolve_chunk(), dyad_cap)
        # device-resident dyad list length: the dyad-count bucket rounded up
        # to whole chunks, so every chunk's dynamic_slice stays in bounds
        # (and the shape stays a pure function of the metadata buckets).
        d_bucket = max(1, meta.m_nbr_bucket // 2)
        self.dyad_pad = max(self.chunk, -(-d_bucket // self.chunk) * self.chunk)
        self.device_path = config.resolve_device_accum()
        self.stats = {"traces": 0, "runs": 0, "chunks": 0, "host_syncs": 0,
                      "batch_runs": 0, "batch_graphs": 0}
        self._batch_fn = None  # lazily-built vmapped unit (xla device path)
        # distributed: per-shard load summary of the most recent run
        # (a backends.TaskStats — plans are cached with a bounded LRU, so
        # only the (n_shards,) weights are retained, never the task arrays).
        self.last_task_stats = None
        if backend == "xla":
            self._fn = (
                backends.make_xla_stream_fn(meta, config, self.stats,
                                            self.chunk)
                if self.device_path
                else backends.make_xla_chunk_fn(meta, config, self.stats))
        elif backend == "distributed":
            if mesh is None:
                raise ValueError("distributed backend needs a mesh")
            make = (backends.make_distributed_stream_fn if self.device_path
                    else backends.make_distributed_chunk_fn)
            self._fn = make(meta, config, mesh, self.stats)
        elif backend == "pallas":
            self._fn = None  # pallas_call manages its own per-shape cache
        else:
            raise ValueError(f"unknown backend {backend!r}")

    # -- graph admission -----------------------------------------------------

    def _check(self, g: CSRGraph):
        m = self.meta
        if g.max_deg > m.k:
            raise ValueError(
                f"graph max_deg={g.max_deg} exceeds plan tile width k={m.k}; "
                f"recompile with compile_census(graph, config)")
        if g.n > m.n_bucket or g.m > m.m_out_bucket or g.m_nbr > m.m_nbr_bucket:
            raise ValueError(
                f"graph (n={g.n}, m={g.m}, m_nbr={g.m_nbr}) exceeds plan "
                f"buckets {m}; recompile with compile_census(graph, config)")

    def padded_arrays_host(self, g: CSRGraph) -> GraphArrays:
        """Bucket-padded arrays as host numpy (no device transfer).

        The batched path (:func:`repro.engine.backends.run_xla_batch`)
        pads + stacks a whole batch on host and ships **one** device put
        per field — per-graph puts would otherwise dominate small-graph
        fleet serving.  Padding semantics match :meth:`padded_arrays`.
        """
        m = self.meta
        a = g.arrays
        out_ptr = np.asarray(a.out_ptr)
        nbr_ptr = np.asarray(a.nbr_ptr)
        return GraphArrays(
            out_ptr=_pad_to(out_ptr, m.n_bucket + 1, out_ptr[-1]),
            out_idx=_pad_to(np.asarray(a.out_idx), m.m_out_bucket, 0),
            nbr_ptr=_pad_to(nbr_ptr, m.n_bucket + 1, nbr_ptr[-1]),
            nbr_idx=_pad_to(np.asarray(a.nbr_idx), m.m_nbr_bucket, 0),
            nbr_deg=_pad_to(np.asarray(a.nbr_deg), m.n_bucket, 0),
        )

    def padded_arrays(self, g: CSRGraph, *,
                      with_in_csr: Optional[bool] = None) -> GraphArrays:
        """Device arrays padded to the metadata buckets (shape-stable).

        Padded ptr rows repeat the last offset (empty rows: binary search
        sees lo == hi and never matches); padded idx/deg entries are inert.

        ``with_in_csr`` additionally populates the transpose (in-arc) CSR
        fields, built **on device** by
        :func:`repro.kernels.ops.build_in_csr_device` — once per run, no
        host round trip.  Default: only for the device-resident pallas
        path, the one consumer of in-arc tiles.
        """
        host = self.padded_arrays_host(g)
        arrays = GraphArrays(
            **{f: (None if v is None else jnp.asarray(v))
               for f, v in zip(GraphArrays._fields, host)})
        if with_in_csr is None:
            with_in_csr = self.backend == "pallas" and self.device_path
        if with_in_csr:
            from ..kernels import ops
            in_ptr, in_idx = ops.build_in_csr_device(arrays.out_ptr,
                                                     arrays.out_idx)
            arrays = arrays._replace(in_ptr=in_ptr, in_idx=in_idx)
        return arrays

    # -- execution -----------------------------------------------------------

    def run(self, g: CSRGraph) -> CensusResult:
        """Execute the census; returns int64 counts for all 16 triad types.

        Semantically the ``B = 1`` case of :meth:`run_batch`; it executes
        through the single-graph (un-vmapped) units, which produce
        bit-identical counts — the census is pure integer arithmetic.
        """
        self._check(g)
        self.stats["runs"] += 1
        return self._run_one(g)

    def _run_one(self, g: CSRGraph) -> CensusResult:
        """Backend dispatch + the type-003 closed form (stats pre-counted)."""
        runner = {"xla": backends.run_xla,
                  "distributed": backends.run_distributed,
                  "pallas": backends.run_pallas}[self.backend]
        counts = runner(self, g)
        # the paper's line 29: null triads via the closed form, on host.
        counts[0] = _c3(g.n) - int(counts.sum())
        return CensusResult(counts=counts)

    def run_batch(self, graphs) -> "list[CensusResult]":
        """Execute the census on B same-bucket graphs as one batch.

        Every graph must pass this plan's admission check (same metadata
        buckets — the :class:`GraphMeta` grouping a
        :class:`repro.serve.CensusService` performs).  On the xla
        device-resident path the whole batch runs through one vmapped
        fixed-shape unit — a leading batch axis over the padded graph
        arrays, the device dyad lists and the 16-bin hi/lo accumulator —
        so B requests cost one chunk schedule of dispatches and **one**
        device→host transfer instead of B of each.  Results are
        bit-identical to B sequential :meth:`run` calls (integer
        arithmetic; excess chunks for shorter graphs are masked no-ops).

        The pallas / distributed backends and the synchronous baseline
        (``device_accum=False``) have no vmapped unit yet; there the batch
        executes member-wise through the single-graph path — same results,
        amortizing only the plan, not the dispatch.

        Returns one :class:`CensusResult` per graph, in input order.
        """
        graphs = list(graphs)
        if not graphs:
            return []
        for g in graphs:
            self._check(g)
        self.stats["runs"] += len(graphs)
        self.stats["batch_runs"] += 1
        self.stats["batch_graphs"] += len(graphs)
        if self.backend == "xla" and self.device_path:
            counts = backends.run_xla_batch(self, graphs)
            out = []
            for g, c in zip(graphs, counts):
                c = c.copy()
                c[0] = _c3(g.n) - int(c.sum())
                out.append(CensusResult(counts=c))
            return out
        return [self._run_one(g) for g in graphs]

    def batch_fn(self):
        """The vmapped batched unit (xla device path), built lazily.

        One jitted callable serves every batch size — jit retraces per
        distinct (power-of-two-padded) B, counted in ``stats['traces']``.
        """
        if self._batch_fn is None:
            self._batch_fn = backends.make_xla_stream_batch_fn(
                self.meta, self.config, self.stats, self.chunk)
        return self._batch_fn

    def aot_lower(self, g: CSRGraph):
        """Lower the compiled chunk unit at this plan's static shapes.

        For dry-run/roofline analysis (memory_analysis, cost_analysis)
        without executing.  Only xla/distributed expose a jitted unit.
        """
        if self._fn is None:
            raise NotImplementedError("pallas backend has no jitted unit")
        m = self.meta
        arrays = GraphArrays(
            out_ptr=jax.ShapeDtypeStruct((m.n_bucket + 1,), jnp.int32),
            out_idx=jax.ShapeDtypeStruct((m.m_out_bucket,), jnp.int32),
            nbr_ptr=jax.ShapeDtypeStruct((m.n_bucket + 1,), jnp.int32),
            nbr_idx=jax.ShapeDtypeStruct((m.m_nbr_bucket,), jnp.int32),
            nbr_deg=jax.ShapeDtypeStruct((m.n_bucket,), jnp.int32),
        )
        n = jax.ShapeDtypeStruct((), jnp.int32)
        if self.backend == "distributed":
            n_dev = math.prod(self.mesh.devices.shape)
            shape = (n_dev, backends.chunk_l(self))
        else:
            shape = (self.chunk,)
        ints = jax.ShapeDtypeStruct(shape, jnp.int32)
        bools = jax.ShapeDtypeStruct(shape, jnp.bool_)
        if not self.device_path:
            return self._fn.lower(arrays, n, ints, ints, bools)
        scalar = jax.ShapeDtypeStruct((), jnp.int32)
        acc = jax.ShapeDtypeStruct((16,), jnp.int32)
        if self.backend == "distributed":
            return self._fn.lower(arrays, n, ints, ints, bools, acc, acc)
        dyads = jax.ShapeDtypeStruct((self.dyad_pad,), jnp.int32)
        return self._fn.lower(arrays, n, dyads, dyads, scalar, scalar,
                              acc, acc)


# ----------------------------------------------------------------------------
# plan cache (bounded LRU)
# ----------------------------------------------------------------------------

_PLAN_CACHE: collections.OrderedDict = collections.OrderedDict()
_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}
_DEFAULT_CAPACITY = 32
_CACHE_CAPACITY = _DEFAULT_CAPACITY


def set_plan_cache_capacity(capacity: int) -> None:
    """Bound the plan cache to ``capacity`` entries (LRU eviction).

    Long-lived multi-graph services would otherwise accumulate one
    compiled plan (and its XLA executable) per distinct metadata bucket
    forever.  Shrinking the capacity evicts the least-recently-used plans
    immediately; evictions are counted in :func:`plan_cache_stats`.
    """
    global _CACHE_CAPACITY
    if capacity < 1:
        raise ValueError("plan cache capacity must be >= 1")
    _CACHE_CAPACITY = capacity
    _evict_to_capacity()


def _evict_to_capacity() -> None:
    while len(_PLAN_CACHE) > _CACHE_CAPACITY:
        _PLAN_CACHE.popitem(last=False)
        _CACHE_STATS["evictions"] += 1


@functools.lru_cache(maxsize=8)
def _default_mesh(n_dev: int):
    return jax.make_mesh((n_dev,), ("data",))


def compile_census(graph_meta, config: Optional[CensusConfig] = None, *,
                   mesh=None) -> CensusPlan:
    """Build (or fetch from cache) the census plan for this graph shape.

    ``graph_meta`` is a :class:`CSRGraph` (metadata extracted and
    bucketized) or an explicit :class:`GraphMeta`.  Plans are cached on
    (metadata buckets, config, resolved backend, mesh): a second census on
    a same-shape graph returns the identical plan object and re-uses its
    compiled trace.
    """
    config = config or CensusConfig()
    meta = (graph_meta if isinstance(graph_meta, GraphMeta)
            else GraphMeta.from_graph(graph_meta, k=config.k))
    backend = config.resolve_backend()
    # normalize: an "auto" config and the explicit backend it resolves to
    # must share one cache entry (and one compiled plan); likewise
    # device_accum=None and the True it resolves to.
    config = dataclasses.replace(
        config, backend=backend,
        device_accum=config.resolve_device_accum())
    if backend == "distributed" and mesh is None:
        mesh = _default_mesh(len(jax.devices()))
    key = (meta, config, mesh)
    plan = _PLAN_CACHE.get(key)
    if plan is not None:
        _CACHE_STATS["hits"] += 1
        _PLAN_CACHE.move_to_end(key)  # LRU freshness
        return plan
    _CACHE_STATS["misses"] += 1
    plan = CensusPlan(meta, config, backend, mesh)
    _PLAN_CACHE[key] = plan
    _evict_to_capacity()
    return plan


def clear_plan_cache() -> None:
    """Drop every cached plan and reset hit/miss/eviction counters.

    Compiled XLA executables owned by the dropped plans become garbage;
    use in tests/benchmarks to force cold compiles.
    """
    _PLAN_CACHE.clear()
    _CACHE_STATS.update(hits=0, misses=0, evictions=0)


def plan_cache_stats() -> dict:
    """Plan-cache counters plus per-entry (per-bucket) metadata.

    Returns ``hits`` / ``misses`` / ``evictions`` / ``size`` /
    ``capacity`` plus ``entries``: one dict per cached plan, in LRU order
    (oldest first), holding the bucketized ``meta`` fields, ``backend``,
    ``device_path``, the resolved streaming ``chunk``, and the plan's
    live execution counters (``runs``, ``batch_runs``, ``batch_graphs``,
    ``traces``, ``chunks``, ``host_syncs``).  This is the introspection
    surface :class:`repro.serve.CensusService` reports per-bucket stats
    from.
    """
    entries = [
        dict(meta=dataclasses.asdict(p.meta), backend=p.backend,
             device_path=p.device_path, chunk=p.chunk, **p.stats)
        for p in _PLAN_CACHE.values()
    ]
    return {**_CACHE_STATS, "size": len(_PLAN_CACHE),
            "capacity": _CACHE_CAPACITY, "entries": entries}
