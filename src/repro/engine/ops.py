"""Pluggable graph analytics: the GraphOp protocol, registry, and built-ins.

The paper's bottom line is that irregular graph analytics are
*memory-bound*: streaming the dyad/neighborhood structure through the
device dominates, while the per-element arithmetic is almost free (Green
et al., arXiv:1910.03679, make the same point for memory channels).  A
:class:`GraphOp` therefore declares three small pieces and lets the
engine amortize the expensive part — the traversal — across every
analytic that wants it, the way Chin et al. (arXiv:1209.6308) run a whole
triadic-analysis family over one pass:

  * ``make_batch_fn`` — the per-chunk device kernel: a pure function of a
    batch of canonical dyads ``(u, v), u < v`` returning ``(bins,)``
    partial counts (additive, non-negative, < 2**30 per fold so the
    engine's int32 hi/lo accumulator stays exact);
  * ``make_once_fn`` — an optional per-run device contribution (for
    vertex-space analytics such as degree statistics), folded into the
    on-device accumulator exactly once per run, before the chunk loop;
  * ``finalize`` — the host-side step from raw int64 bins to the op's
    result object (closed forms live here).

``repro.engine.compile(graph, ops, EngineConfig())`` fuses any number of
ops into ONE pass over the streaming dyad pipeline: one traversal, one
on-device hi/lo accumulator (each op owns a slice — see
:class:`OpLayout`), one device→host transfer.  Ops that declare the same
``kernel_key`` share one kernel and one accumulator slice
(``triadic_profile`` rides the ``triad_census`` bins for free).

Contract corner: chunks only run when the graph has dyads, so on an
arc-free graph the raw bins arrive all-zero — ``finalize`` must
reconstruct the correct result from zeros whenever ``g.m == 0``.  Every
op also ships a NumPy ``reference`` oracle; the parity suite
(``tests/test_ops.py``) holds each backend to it bit for bit.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.census import (CensusResult, brute_force_census,
                           make_census_batch_fn, make_member_fn)
from ..core.graph import CSRGraph, dense_adjacency
from ..core.triad_table import TRIAD_NAMES

__all__ = ["DegreeStats", "DyadCensus", "GraphOp", "OpLayout",
           "TriadicProfile", "get_op", "list_ops", "register_op",
           "resolve_ops", "unregister_op"]


def _c2(n: int) -> int:
    return n * (n - 1) // 2 if n >= 2 else 0


def _c3(n: int) -> int:
    return n * (n - 1) * (n - 2) // 6 if n >= 3 else 0


# ----------------------------------------------------------------------------
# result types
# ----------------------------------------------------------------------------


class DyadCensus(NamedTuple):
    """MAN dyad census over all C(n, 2) vertex pairs (paper Ch. 2): a pair
    is **mutual** when both arcs exist, **asymmetric** when exactly one
    does, **null** otherwise.  ``mutual + asymmetric + null == C(n, 2)``;
    null pairs come from the closed form (they never enter the dyad
    stream, which only holds connected pairs)."""

    mutual: int
    asymmetric: int
    null: int


class DegreeStats(NamedTuple):
    """In/out-degree summary of the directed graph.

    ``out_hist`` / ``in_hist`` are log2 histograms over the n vertices:
    bin 0 counts degree-0 vertices, bin b (b >= 1) counts degrees in
    ``[2**(b-1), 2**b)``, and the top bin absorbs everything larger.
    ``mean_out == mean_in == m / n`` (every arc is one out- and one
    in-endpoint), computed on host."""

    out_hist: np.ndarray  # (16,) int64
    in_hist: np.ndarray   # (16,) int64
    max_out: int
    max_in: int
    mean_out: float
    mean_in: float


class TriadicProfile(NamedTuple):
    """Transitivity profile derived from the 16 triad-census bins.

    Over the underlying undirected graph (a dyad is "connected" when
    mutual or asymmetric): ``triangles`` = triads whose three dyads are
    all connected, ``open_triples`` = wedges not closed into a triangle,
    ``transitivity`` = 3 * triangles / (3 * triangles + open_triples)
    (the global clustering coefficient), ``triangle_density`` =
    triangles / C(n, 3)."""

    triangles: int
    open_triples: int
    transitivity: float
    triangle_density: float


# ----------------------------------------------------------------------------
# the GraphOp protocol
# ----------------------------------------------------------------------------


class GraphOp:
    """One pluggable analytic: per-chunk kernel + per-run contribution +
    host finalize.

    Subclass, set ``name`` / ``bins`` (accumulator width), override any of
    :meth:`make_batch_fn` / :meth:`make_once_fn` / :meth:`finalize` /
    :meth:`reference`, and :func:`register_op` an instance — every engine
    entry point (``compile``, ``CensusService`` requests, benchmarks) then
    accepts the op by name and fuses it into the shared streaming pass.
    Set ``kernel_key`` to another op's name to share that op's device
    kernel and accumulator slice (``finalize`` then reads the shared raw
    bins — how ``triadic_profile`` derives from ``triad_census``)."""

    name: str = ""
    bins: int = 0
    kernel_key: Optional[str] = None  # None -> own kernel, keyed by name
    #: Locality contract for the incremental delta engine
    #: (:mod:`repro.engine.delta`).  ``True`` promises that the batch
    #: kernel's contribution for a dyad ``(u, v)`` depends only on ``n``
    #: and the arcs between ``{u, v}`` and ``{u, v} ∪ N(u) ∪ N(v)`` (and
    #: that any ``once`` contribution is a whole-graph function the delta
    #: pass may recompute outright — it is, both versions are folded).
    #: Every built-in op satisfies this — their kernels only probe the
    #: dyad's own arcs and membership against the open neighborhoods.  An
    #: op whose kernel reads structure beyond that horizon must set
    #: ``False``; ``Plan.apply_delta`` then always takes the full-recompute
    #: path, which is correct for any op.
    delta_local: bool = True

    def make_batch_fn(self, meta, config) -> Optional[Callable]:
        """Build the per-chunk device kernel, or ``None`` if the op has no
        per-dyad component.

        The kernel maps ``(graph_arrays, n, u, v, valid)`` — a batch of
        canonical dyads, padded lanes masked by ``valid`` — to ``(bins,)``
        partial counts in ``config.acc_jnp_dtype``.  It must be additive
        across batches, order-independent, and keep every per-fold value
        in ``[0, 2**30)``."""
        return None

    def make_once_fn(self, meta, config) -> Optional[Callable]:
        """Build the optional per-run device contribution, or ``None``.

        ``(graph_arrays, n) -> (bins,)`` — folded into the on-device
        accumulator exactly once per run, before the chunk loop, for
        vertex-space analytics that need no dyad stream.  Same value
        constraints as the batch kernel.  Note padded-array conventions: ``out_ptr[-1]``
        is the true arc count, vertices at index >= ``n`` are padding."""
        return None

    def finalize(self, raw: np.ndarray, g: CSRGraph) -> Any:
        """Host-side step from raw int64 bins to the op's result object.

        Closed forms live here (null triads/dyads, means).  Must produce
        the correct result from all-zero ``raw`` when ``g.m == 0`` —
        chunks never run on arc-free graphs."""
        raise NotImplementedError

    def unpermute_raw(self, raw: np.ndarray, perm: np.ndarray,
                      g: CSRGraph) -> np.ndarray:
        """Map this kernel's raw bins from relabeled vertex space back to
        the original — the inverse-permutation hook for the engine's
        ``reorder=`` preprocessing (:mod:`repro.core.reorder`).

        ``perm[old_id] = new_id`` is the relabeling execution ran under.
        The default is the identity: every built-in op's bins are
        vertex-anonymous aggregates (census counts, degree *histograms*),
        which a relabeling cannot move between bins.  An op whose slice
        is vertex-indexed (bin ``i`` belongs to vertex ``i``) must
        override with the gather ``out[:n] = raw[perm]`` so its raw
        contract stays ORIGINAL vertex ids under any ``reorder=``
        strategy.  Must be linear in ``raw`` (a fixed gather/identity) —
        the delta engine relies on ``unpermute(a + b) == unpermute(a) +
        unpermute(b)`` to fold corrections computed in relabeled space."""
        return raw

    def reference(self, g: CSRGraph) -> Any:
        """NumPy oracle: the op's result computed host-side, for parity
        tests and docs.  Intended for small graphs only."""
        raise NotImplementedError


# ----------------------------------------------------------------------------
# built-in ops
# ----------------------------------------------------------------------------


class TriadCensusOp(GraphOp):
    """The paper's analytic: the 16-type Batagelj–Mrvar triad census.

    Per-chunk kernel is :func:`repro.core.census.make_census_batch_fn`
    (the one algorithm definition every backend executes); finalize
    applies the type-003 closed form (paper line 29)."""

    name = "triad_census"
    bins = 16

    def make_batch_fn(self, meta, config):
        return make_census_batch_fn(meta.k, meta.member_iters,
                                    config.acc_jnp_dtype)

    def finalize(self, raw: np.ndarray, g: CSRGraph) -> CensusResult:
        counts = raw.astype(np.int64).copy()
        counts[0] = _c3(g.n) - int(counts.sum())
        return CensusResult(counts=counts)

    def reference(self, g: CSRGraph) -> CensusResult:
        return brute_force_census(g)


class DyadCensusOp(GraphOp):
    """MAN dyad census (paper Ch. 2): mutual / asymmetric / null pair
    counts.  Two ``IsEdge`` probes per streamed dyad; null pairs via the
    C(n, 2) closed form in finalize."""

    name = "dyad_census"
    bins = 3  # [mutual, asymmetric, 0]; null from the closed form

    def make_batch_fn(self, meta, config):
        member = make_member_fn(meta.member_iters)
        acc = config.acc_jnp_dtype

        def dyad_fn(arrays, n, u, v, valid):
            e_uv = member(arrays.out_ptr, arrays.out_idx, u, v)
            e_vu = member(arrays.out_ptr, arrays.out_idx, v, u)
            mut = (e_uv & e_vu & valid).sum(dtype=acc)
            asym = ((e_uv ^ e_vu) & valid).sum(dtype=acc)
            return jnp.stack([mut, asym, jnp.zeros((), acc)])

        return dyad_fn

    def finalize(self, raw: np.ndarray, g: CSRGraph) -> DyadCensus:
        mutual, asymmetric = int(raw[0]), int(raw[1])
        return DyadCensus(mutual, asymmetric,
                          _c2(g.n) - mutual - asymmetric)

    def reference(self, g: CSRGraph) -> DyadCensus:
        a = dense_adjacency(g)
        mutual = int(np.logical_and(a, a.T).sum()) // 2
        asymmetric = int(np.logical_and(a, ~a.T).sum())
        return DyadCensus(mutual, asymmetric,
                          _c2(g.n) - mutual - asymmetric)


class DegreeStatsOp(GraphOp):
    """In/out-degree histograms + maxima — a pure vertex-space analytic,
    expressed as a per-run ``once`` contribution (no per-dyad kernel):
    the fused pass computes it on device for free alongside the dyad
    stream.  In-degrees come from a device scatter-add over the out-arc
    column array (no transpose CSR needed)."""

    name = "degree_stats"
    H = 16  # log2 histogram bins (see DegreeStats)
    bins = 2 * H + 2  # out_hist, in_hist, max_out, max_in

    def make_once_fn(self, meta, config):
        H, acc = self.H, config.acc_jnp_dtype

        def once(arrays, n):
            nb = arrays.out_ptr.shape[0] - 1
            vmask = jnp.arange(nb, dtype=jnp.int32) < n
            out_deg = arrays.out_ptr[1:] - arrays.out_ptr[:-1]
            m = arrays.out_ptr[-1]  # padded rows repeat the last offset
            pos = jnp.arange(arrays.out_idx.shape[0], dtype=jnp.int32)
            in_deg = (jnp.zeros(nb, jnp.int32)
                      .at[arrays.out_idx].add(jnp.where(pos < m, 1, 0)))
            live = vmask.astype(acc)
            shifts = jnp.arange(H - 1, dtype=jnp.int32)

            def hist(deg):
                # bin = min(bit_length(deg), H-1); 0 stays in bin 0.
                b = jnp.sum((deg[:, None] >> shifts[None, :]) > 0, axis=1)
                return jnp.zeros(H, acc).at[b].add(live)

            def mx(deg):
                return jnp.max(jnp.where(vmask, deg, 0)).astype(acc)

            return jnp.concatenate([hist(out_deg), hist(in_deg),
                                    mx(out_deg)[None], mx(in_deg)[None]])

        return once

    def finalize(self, raw: np.ndarray, g: CSRGraph) -> DegreeStats:
        H = self.H
        if g.m == 0:  # no chunks ran: all n vertices sit in bin 0
            out_hist = np.zeros(H, np.int64)
            out_hist[0] = g.n
            in_hist = out_hist.copy()
            mx_out = mx_in = 0
        else:
            raw = raw.astype(np.int64)
            out_hist, in_hist = raw[:H].copy(), raw[H:2 * H].copy()
            mx_out, mx_in = int(raw[2 * H]), int(raw[2 * H + 1])
        mean = g.m / g.n if g.n else 0.0
        return DegreeStats(out_hist, in_hist, mx_out, mx_in, mean, mean)

    def reference(self, g: CSRGraph) -> DegreeStats:
        H = self.H
        out_ptr = np.asarray(g.arrays.out_ptr)[: g.n + 1]
        out_deg = np.diff(out_ptr).astype(np.int64)
        idx = np.asarray(g.arrays.out_idx)[: g.m]
        in_deg = np.bincount(idx, minlength=g.n)[: g.n].astype(np.int64)

        def hist(d):
            b = np.where(d == 0, 0, np.minimum(
                np.floor(np.log2(np.maximum(d, 1))).astype(np.int64) + 1,
                H - 1))
            return np.bincount(b, minlength=H)[:H].astype(np.int64)

        mean = g.m / g.n if g.n else 0.0
        return DegreeStats(hist(out_deg), hist(in_deg),
                           int(out_deg.max(initial=0)),
                           int(in_deg.max(initial=0)), mean, mean)


#: connected (mutual + asymmetric) dyads per triad type, from the MAN name.
_CONNECTED = tuple(int(nm[0]) + int(nm[1]) for nm in TRIAD_NAMES)


class TriadicProfileOp(GraphOp):
    """Transitivity + triangle statistics, derived from the census bins.

    Declares ``kernel_key = "triad_census"``: it runs no kernel of its
    own — when fused with ``triad_census`` the two ops share one kernel
    and one accumulator slice, and alone it reuses the census kernel.
    Finalize weighs each triad type by its connected-dyad count (the
    MAN-name digit sum): 3 connected dyads = a triangle (3 closed
    wedges), 2 = one open wedge."""

    name = "triadic_profile"
    kernel_key = "triad_census"
    bins = 16

    def make_batch_fn(self, meta, config):
        return make_census_batch_fn(meta.k, meta.member_iters,
                                    config.acc_jnp_dtype)

    def _profile(self, counts, n: int) -> TriadicProfile:
        tri = sum(int(c) for c, k in zip(counts, _CONNECTED) if k == 3)
        wedges = sum(int(c) * (3 if k == 3 else 1)
                     for c, k in zip(counts, _CONNECTED) if k >= 2)
        transitivity = 3.0 * tri / wedges if wedges else 0.0
        density = tri / _c3(n) if n >= 3 else 0.0
        return TriadicProfile(tri, wedges - 3 * tri, transitivity, density)

    def finalize(self, raw: np.ndarray, g: CSRGraph) -> TriadicProfile:
        # raw bin 0 ("003") is always 0 on the kernel path and its
        # connected weight is 0 anyway, so no closed form is needed.
        return self._profile(raw, g.n)

    def reference(self, g: CSRGraph) -> TriadicProfile:
        return self._profile(brute_force_census(g).counts, g.n)


# ----------------------------------------------------------------------------
# registry
# ----------------------------------------------------------------------------

_REGISTRY: "dict[str, GraphOp]" = {}


def register_op(op: GraphOp, *, overwrite: bool = False) -> GraphOp:
    """Register a :class:`GraphOp` instance under ``op.name``.

    Registered ops are addressable by name everywhere an ``ops`` argument
    is accepted (``repro.engine.compile``, ``CensusService.submit``,
    ``benchmarks/run.py --ops``).  Returns ``op`` so the call can be used
    as a statement-level decorator on an instance."""
    if not op.name:
        raise ValueError("GraphOp needs a non-empty name")
    if op.bins < 1:
        raise ValueError(f"GraphOp {op.name!r} needs bins >= 1")
    if op.name in _REGISTRY and not overwrite:
        raise ValueError(f"GraphOp {op.name!r} is already registered "
                         "(pass overwrite=True to replace)")
    _REGISTRY[op.name] = op
    return op


def unregister_op(name: str) -> None:
    """Remove a registered op (no-op if absent).  Plans already compiled
    against the op keep working; only name lookup is affected."""
    _REGISTRY.pop(name, None)


def get_op(name: str) -> GraphOp:
    """Look up a registered :class:`GraphOp` by name (KeyError with the
    registered-name list otherwise)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown GraphOp {name!r}; registered: "
                       f"{list_ops()}") from None


def list_ops() -> "tuple[str, ...]":
    """Names of every registered :class:`GraphOp`, sorted."""
    return tuple(sorted(_REGISTRY))


def resolve_ops(ops) -> "tuple[GraphOp, ...]":
    """Normalize an ops spec — a name, a :class:`GraphOp` instance, or a
    sequence of either — into a tuple of op instances (order preserved;
    duplicates rejected)."""
    if isinstance(ops, (str, GraphOp)):
        ops = (ops,)
    out = tuple(get_op(o) if isinstance(o, str) else o for o in ops)
    if not out:
        raise ValueError("ops must name at least one GraphOp")
    for op in out:
        if not isinstance(op, GraphOp):
            raise TypeError(f"ops entries must be GraphOp names or "
                            f"instances, got {op!r}")
    names = [op.name for op in out]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate ops in {names}")
    return out


for _op in (TriadCensusOp(), DyadCensusOp(), DegreeStatsOp(),
            TriadicProfileOp()):
    register_op(_op)


# ----------------------------------------------------------------------------
# fused accumulator layout
# ----------------------------------------------------------------------------


class OpLayout:
    """Accumulator layout + fused kernels for one plan's ops.

    Ops are deduplicated by ``kernel_key`` (first op bearing a key owns
    its kernel); each unique kernel gets a contiguous slice of the fused
    accumulator.  :meth:`batch_kernel` / :meth:`once_kernel` concatenate
    the per-kernel contributions into one ``(total_bins,)`` vector — the
    quantity the engine's hi/lo accumulator folds per batch — and
    :meth:`finalize` hands each op its slice of the raw int64 bins."""

    def __init__(self, ops, meta, config):
        self.ops = tuple(ops)
        owners: dict = {}
        self.keys: list = []
        for op in self.ops:
            key = op.kernel_key or op.name
            if key not in owners:
                owners[key] = op
                self.keys.append(key)
            elif op.name == key:
                owners[key] = op  # a key's namesake always owns its kernel
        for op in self.ops:
            key = op.kernel_key or op.name
            if op.bins != owners[key].bins:
                raise ValueError(
                    f"op {op.name!r} shares kernel_key {key!r} but declares "
                    f"bins={op.bins} != {owners[key].bins} (the kernel "
                    f"owner's width) — sharers read the owner's slice and "
                    f"must agree on its size")
        self._owners = owners
        self.bins = tuple(owners[k].bins for k in self.keys)
        edges = np.concatenate([[0], np.cumsum(self.bins)])
        self.slices = {k: slice(int(edges[i]), int(edges[i + 1]))
                       for i, k in enumerate(self.keys)}
        self.total_bins = int(edges[-1])
        self._acc = config.acc_jnp_dtype
        self._batch_fns = [owners[k].make_batch_fn(meta, config)
                           for k in self.keys]
        self._once_fns = [owners[k].make_once_fn(meta, config)
                          for k in self.keys]
        self.has_once = any(f is not None for f in self._once_fns)
        self._once_jit = None
        self._once_batch_jit = None

    def has_batch(self, *, skip=()) -> bool:
        """True if any kernel outside ``skip`` has a per-dyad component."""
        return any(f is not None for k, f in zip(self.keys, self._batch_fns)
                   if k not in skip)

    def batch_kernel(self, *, skip=()):
        """Fused per-batch kernel ``(arrays, n, u, v, valid) ->
        (total_bins,)``.  Keys in ``skip`` contribute zeros — the pallas
        backend skips ``"triad_census"`` here and fills that slice with
        its tile kernel instead."""
        fns = [None if k in skip else f
               for k, f in zip(self.keys, self._batch_fns)]
        bins, acc = self.bins, self._acc

        def fused(arrays, n, u, v, valid):
            parts = [f(arrays, n, u, v, valid) if f is not None
                     else jnp.zeros((b,), acc) for f, b in zip(fns, bins)]
            return parts[0] if len(parts) == 1 else jnp.concatenate(parts)

        return fused

    def once_kernel(self):
        """Fused per-run kernel ``(arrays, n) -> (total_bins,)``, or
        ``None`` when no op declares a once contribution."""
        if not self.has_once:
            return None
        fns, bins, acc = self._once_fns, self.bins, self._acc

        def fused(arrays, n):
            parts = [f(arrays, n) if f is not None
                     else jnp.zeros((b,), acc) for f, b in zip(fns, bins)]
            return parts[0] if len(parts) == 1 else jnp.concatenate(parts)

        return fused

    def once_jitted(self):
        """Jitted :meth:`once_kernel`, cached on the layout — the drivers
        fold it into the accumulator once per run, before the chunk loop
        (chunk units carry no once logic, so its vertex-space work is
        never re-dispatched per chunk)."""
        if self._once_jit is None and self.has_once:
            self._once_jit = jax.jit(self.once_kernel())
        return self._once_jit

    def once_batch_jitted(self):
        """Vmapped + jitted :meth:`once_kernel` for the batched driver
        (leading batch axis over arrays and ``n``; padding lanes have
        ``n = 0`` so every per-vertex contribution masks to zero)."""
        if self._once_batch_jit is None and self.has_once:
            self._once_batch_jit = jax.jit(jax.vmap(self.once_kernel()))
        return self._once_batch_jit

    def unpermute(self, raw, perm, g: CSRGraph) -> np.ndarray:
        """Map fused raw bins from relabeled vertex space back to the
        original, slice by slice, through each kernel owner's
        :meth:`GraphOp.unpermute_raw` hook.  Returns ``raw`` unchanged
        (no copy) when every owner keeps the identity default — the case
        for all built-in ops, whose bins are vertex-anonymous."""
        out = None
        for k in self.keys:
            op = self._owners[k]
            if type(op).unpermute_raw is GraphOp.unpermute_raw:
                continue
            if out is None:
                out = np.array(raw, dtype=np.int64, copy=True)
            sl = self.slices[k]
            out[sl] = np.asarray(op.unpermute_raw(out[sl], perm, g),
                                 dtype=np.int64)
        return raw if out is None else out

    def finalize(self, raw, g: CSRGraph) -> dict:
        """Per-op results from the fused raw bins: ``{op.name: result}``
        in the plan's op order."""
        raw = np.asarray(raw, dtype=np.int64)
        return {op.name:
                op.finalize(raw[self.slices[op.kernel_key or op.name]], g)
                for op in self.ops}
