"""Degree-aware adaptive chunk scheduling across a device pool.

The paper's multicore speedups (56x max) come from OpenMP **dynamic
scheduling** of degree-skewed dyad work across hardware threads, and its
GPU results hinge on degree-based load balancing; Dehne & Yogaratnam
(PAPERS.md) identify per-thread work imbalance as the dominant cost for
irregular graphs.  This module is the engine's analogue: an
:class:`Executor` owns a pool of devices and dispatches
:class:`ChunkTask` descriptors — contiguous spans of the device-resident
dyad stream, carved by a *cost model* rather than a fixed ``chunk_size``
(see :func:`repro.core.balance.chunk_bounds_by_cost`) — with a
work-queue policy:

  * ``schedule="static"`` (default): the single-device in-order loop the
    engine always ran — bit-identical to the pre-executor engine, with
    the same double-buffering backpressure (:func:`_throttle`).
  * ``schedule="dynamic"``: one worker thread per pool device pulls the
    next task from a shared queue as soon as its previous dispatch
    clears the pipeline window — the jax analogue of OpenMP
    ``schedule(dynamic)``.  A device stuck on a heavy-degree chunk
    simply pulls fewer chunks; no task assignment is precomputed.

Per-device compiled replicas come for free: the plan's chunk unit is one
``jax.jit`` callable, and jit specializes (and caches) one executable
per committed input device, so the first task a device pulls compiles
its replica and every later task reuses it.

Each worker folds its chunks into a device-local int32 hi/lo
accumulator; the pool merges worker accumulators on the primary device
(:func:`_merge_accs` — exact integer addition, so the merged totals are
bit-identical to the static path for any task-to-device assignment) and
ONE device→host transfer (:func:`_acc_fetch`) completes the run
regardless of pool size.

Exercise the pool on CPU CI with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
from __future__ import annotations

import collections
import threading
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

# the device accumulator is an int32 (hi, lo) pair: count = hi * 2**30 + lo
# with 0 <= lo < 2**30 — exact for totals up to 2**61 without enabling x64.
# Per-fold deltas must stay below 2**30, which holds whenever
# batch * n < 2**30 (the same order of invariant the int32 scan partials
# already required; GraphOp kernels promise the same bound).
_ACC_SHIFT = 30


def _acc_update(hi, lo, delta):
    """Fold a non-negative int32 partial into the hi/lo accumulator."""
    lo = lo + delta.astype(jnp.int32)
    carry = lo >> _ACC_SHIFT
    return hi + carry, lo - (carry << _ACC_SHIFT)


def _acc_fetch(plan, hi, lo) -> np.ndarray:
    """THE device→host transfer of a device-resident run (counted)."""
    plan.stats["host_syncs"] += 1
    packed = np.asarray(jnp.stack([hi, lo]), dtype=np.int64)
    return (packed[0] << _ACC_SHIFT) + packed[1]


@jax.jit
def _merge_accs(hi_t, lo_t, hi_d, lo_d):
    """Fold one worker's hi/lo pair into the pool total (on the primary
    device).  ``lo_d < 2**30`` by the accumulator invariant, so it is a
    valid delta; the hi words add directly.  Pure integer arithmetic —
    the merged total is exact for any partition of the task stream."""
    hi_t, lo_t = _acc_update(hi_t, lo_t, lo_d)
    return hi_t + hi_d, lo_t


def _throttle(window: collections.deque, ref, depth: int) -> None:
    """Double-buffering backpressure: allow ``depth`` chunks in flight.

    Blocks on the dispatch ``depth`` chunks back (a wait, not a transfer)
    so the device work queue stays bounded while chunk ``k + depth`` is
    being enqueued as chunk ``k`` computes.
    """
    window.append(ref)
    if len(window) > max(1, depth):
        window.popleft().block_until_ready()


class ChunkTask(NamedTuple):
    """One schedulable span of the dyad stream: dyads ``[start, end)``,
    its cost-model-predicted work (drives the executor's balance stats),
    and an optional static-argument key (the pallas backend stores the
    bucket tile width ``K`` here so each task dispatches the right
    kernel specialization)."""

    start: int
    end: int
    cost: float = 0.0
    key: Optional[int] = None


class Executor:
    """A device pool + dispatch policy for one plan's chunk tasks.

    Built by :class:`repro.engine.plan.Plan` from its
    :class:`~repro.engine.EngineConfig` (``schedule``,
    ``n_executor_devices``); the distributed backend pins the pool to a
    single slot because its mesh already owns every device (shard_map is
    the parallelism there — the executor contributes only the chunk
    loop).  See the module docstring for the scheduling policies.

    :meth:`run` drives ``step(ctx, hi, lo, task) -> (hi, lo)`` over the
    task list, where ``ctx = place(device)`` is the backend's
    device-resident context (graph arrays + dyad stream; ``place(None)``
    must return the default-placement context unchanged — that keeps the
    static path free of extra transfers).  Dispatch counts land in
    ``stats["device_chunks"]`` (``{device index: chunks}``) — the
    occupancy signal :meth:`repro.serve.CensusService.stats` aggregates.
    """

    def __init__(self, config, stats: dict, *, n_devices: int = 1):
        self.schedule = config.schedule
        self.depth = max(1, config.pipeline_depth)
        n = max(1, min(n_devices, len(jax.devices())))
        # a 1-slot pool keeps default placement (device=None): no
        # device_put, no behavior change vs the pre-executor engine.
        self.devices = list(jax.devices()[:n]) if n > 1 else [None]
        self.stats = stats

    @property
    def n_devices(self) -> int:
        """Pool width (1 = default-device in-order dispatch)."""
        return len(self.devices)

    def _bump(self, dev_index: int, count: int) -> None:
        dc = self.stats.setdefault("device_chunks", {})
        dc[dev_index] = dc.get(dev_index, 0) + count

    def run(self, tasks, *, place, step, init):
        """Execute every task; returns the merged (hi, lo) accumulator.

        ``init`` is the run's starting accumulator (it already carries
        the per-run ``once`` contribution) on default placement; the
        result is safe to pass to :func:`_acc_fetch`.
        """
        tasks = list(tasks)
        if len(self.devices) == 1:
            return self._run_inorder(tasks, place, step, init)
        return self._run_workqueue(tasks, place, step, init)

    # -- static: the pre-executor single-device loop, verbatim ---------------

    def _run_inorder(self, tasks, place, step, init):
        ctx = place(self.devices[0])
        hi, lo = init
        window: collections.deque = collections.deque()
        for t in tasks:
            hi, lo = step(ctx, hi, lo, t)
            self.stats["chunks"] += 1
            _throttle(window, hi, self.depth)
        self._bump(0, len(tasks))
        return hi, lo

    # -- dynamic: worker thread per device, shared task queue ----------------

    def _run_workqueue(self, tasks, place, step, init):
        queue: collections.deque = collections.deque(tasks)
        qlock = threading.Lock()
        accs: list = [None] * len(self.devices)
        counts = [0] * len(self.devices)
        errors: list = []

        def worker(i: int, dev) -> None:
            # XLA execution releases the GIL, so worker threads overlap
            # on distinct devices; jit compiles this device's replica on
            # its first task and caches it for the rest of the run.
            try:
                ctx = place(dev)
                acc = jax.device_put((jnp.zeros_like(init[0]),
                                      jnp.zeros_like(init[1])), dev)
                window: collections.deque = collections.deque()
                while True:
                    with qlock:
                        if not queue or errors:
                            break
                        t = queue.popleft()
                    hi, lo = step(ctx, *acc, t)
                    acc = (hi, lo)
                    counts[i] += 1
                    _throttle(window, hi, self.depth)
                accs[i] = acc
            except BaseException as e:  # noqa: BLE001 — ANY escape must
                # surface in the caller's thread: a silently dead worker
                # would otherwise drop every chunk it had folded and the
                # merged run would under-count with no error raised.
                errors.append(e)

        threads = [threading.Thread(target=worker, args=(i, d), daemon=True)
                   for i, d in enumerate(self.devices)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise errors[0]
        self.stats["chunks"] += len(tasks)
        for i, c in enumerate(counts):
            if c:
                self._bump(i, c)
        # merge worker accumulators on the primary device: exact integer
        # folds, so the result is independent of the task assignment.
        hi, lo = init
        primary = self.devices[0]
        for acc in accs:
            if acc is None:
                continue
            hi_d, lo_d = jax.device_put(acc, primary)
            hi, lo = _merge_accs(hi, lo, hi_d, lo_d)
        return hi, lo
