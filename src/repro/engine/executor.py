"""Degree-aware adaptive chunk scheduling across a device pool.

The paper's multicore speedups (56x max) come from OpenMP **dynamic
scheduling** of degree-skewed dyad work across hardware threads, and its
GPU results hinge on degree-based load balancing; Dehne & Yogaratnam
(PAPERS.md) identify per-thread work imbalance as the dominant cost for
irregular graphs.  This module is the engine's analogue: an
:class:`Executor` owns a pool of devices and dispatches
:class:`ChunkTask` descriptors — contiguous spans of the device-resident
dyad stream, carved by a *cost model* rather than a fixed ``chunk_size``
(see :func:`repro.core.balance.chunk_bounds_by_cost`) — with a
work-queue policy:

  * ``schedule="static"`` (default): the single-device in-order loop the
    engine always ran — bit-identical to the pre-executor engine, with
    the same double-buffering backpressure (:func:`_throttle`).
  * ``schedule="dynamic"``: one worker thread per pool device pulls the
    next task from a shared queue as soon as its previous dispatch
    clears the pipeline window — the jax analogue of OpenMP
    ``schedule(dynamic)``.  A device stuck on a heavy-degree chunk
    simply pulls fewer chunks; no task assignment is precomputed.

Per-device compiled replicas come for free: the plan's chunk unit is one
``jax.jit`` callable, and jit specializes (and caches) one executable
per committed input device, so the first task a device pulls compiles
its replica and every later task reuses it.

Each worker folds its chunks into a device-local int32 hi/lo
accumulator; the pool merges worker accumulators on the primary device
(:func:`_merge_accs` — exact integer addition, so the merged totals are
bit-identical to the static path for any task-to-device assignment) and
ONE device→host transfer (:func:`_acc_fetch`) completes the run
regardless of pool size.

**Fault tolerance** (hours-long runs on a pool must survive a failed
kernel launch or a lost device): every chunk dispatch has a bounded
retry budget (``EngineConfig.max_attempts``).  Chunk kernels are
functional — a failed attempt never touches the accumulator — so a
retried chunk folds exactly once and recovered runs stay bit-identical
to fault-free runs, still in one device→host sync.  On the dynamic
schedule a failed task is **re-queued onto surviving devices**; a device
that raises :class:`~repro.engine.faults.DeviceLostError` (or fails
:data:`Executor.QUARANTINE_AFTER` dispatches) is **quarantined** out of
the pool for the rest of the run — its already-folded accumulator stays
valid (only successful folds touched it) and merges normally.  A pool
with every device gone raises :class:`PoolExhaustedError`, which
:meth:`Executor.run` converts into the degradation ladder's
dynamic→static rung (``EngineConfig.schedule_fallback``): the full task
list re-runs in-order on the primary device with device-loss injection
suppressed (fresh-device semantics).  All recovery actions land in
``stats["faults"]`` counters and a bounded ``stats["fault_events"]``
trace — deterministic under a seeded
:class:`~repro.engine.faults.FaultPlan`, which is also how every one of
these paths is exercised in CI (see :mod:`repro.engine.faults`).

Exercise the pool on CPU CI with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .faults import DeviceLostError, InjectedFault, resolve_faults

# the device accumulator is an int32 (hi, lo) pair: count = hi * 2**30 + lo
# with 0 <= lo < 2**30 — exact for totals up to 2**61 without enabling x64.
# Per-fold deltas must stay below 2**30, which holds whenever
# batch * n < 2**30 (the same order of invariant the int32 scan partials
# already required; GraphOp kernels promise the same bound).
_ACC_SHIFT = 30

#: cap on the per-plan fault-event trace (it is a diagnostic, not a log).
_MAX_EVENTS = 512


def _acc_update(hi, lo, delta):
    """Fold a non-negative int32 partial into the hi/lo accumulator."""
    lo = lo + delta.astype(jnp.int32)
    carry = lo >> _ACC_SHIFT
    return hi + carry, lo - (carry << _ACC_SHIFT)


def _acc_fetch(plan, hi, lo) -> np.ndarray:
    """THE device→host transfer of a device-resident run (counted)."""
    plan.stats["host_syncs"] += 1
    packed = np.asarray(jnp.stack([hi, lo]), dtype=np.int64)
    return (packed[0] << _ACC_SHIFT) + packed[1]


@jax.jit
def _merge_accs(hi_t, lo_t, hi_d, lo_d):
    """Fold one worker's hi/lo pair into the pool total (on the primary
    device).  ``lo_d < 2**30`` by the accumulator invariant, so it is a
    valid delta; the hi words add directly.  Pure integer arithmetic —
    the merged total is exact for any partition of the task stream."""
    hi_t, lo_t = _acc_update(hi_t, lo_t, lo_d)
    return hi_t + hi_d, lo_t


def _throttle(window: collections.deque, ref, depth: int) -> None:
    """Double-buffering backpressure: allow ``depth`` chunks in flight.

    Blocks on the dispatch ``depth`` chunks back (a wait, not a transfer)
    so the device work queue stays bounded while chunk ``k + depth`` is
    being enqueued as chunk ``k`` computes.
    """
    window.append(ref)
    if len(window) > max(1, depth):
        window.popleft().block_until_ready()


class WorkerFailures(RuntimeError):
    """Aggregate of *secondary* concurrent worker failures, attached as
    the ``__cause__`` of the primary raised error so a multi-device
    failure is fully diagnosable from one traceback (the pre-fix
    executor raised ``errors[0]`` and silently dropped the rest).  The
    individual exceptions are in ``.errors``."""

    def __init__(self, errors):
        self.errors = list(errors)
        super().__init__(
            f"{len(self.errors)} additional concurrent worker failure(s): "
            + "; ".join(repr(e) for e in self.errors))


class ChunkRetryError(RuntimeError):
    """A chunk kept failing after its full ``max_attempts`` dispatch
    budget (possibly across several pool devices).  The last underlying
    failure is the ``__cause__``; every attempt's exception is in
    ``.attempts``."""

    def __init__(self, message, attempts=()):
        self.attempts = list(attempts)
        super().__init__(message)


class PoolExhaustedError(RuntimeError):
    """Every device in a dynamic-schedule pool was lost or quarantined
    while tasks remained queued.  With
    ``EngineConfig.schedule_fallback=True`` (the default) the executor
    converts this into the ladder's static single-device re-run instead
    of surfacing it."""


def _raise_worker_errors(errors):
    """Raise the primary worker error with any concurrent secondaries
    attached via ``__cause__`` (:class:`WorkerFailures`) — nothing is
    silently dropped."""
    primary, rest = errors[0], errors[1:]
    if rest:
        raise primary from WorkerFailures(rest)
    raise primary


class ChunkTask(NamedTuple):
    """One schedulable span of the dyad stream: dyads ``[start, end)``,
    its cost-model-predicted work (drives the executor's balance stats),
    and an optional static-argument key (the pallas backend stores the
    bucket tile width ``K`` here so each task dispatches the right
    kernel specialization)."""

    start: int
    end: int
    cost: float = 0.0
    key: Optional[int] = None


class Executor:
    """A device pool + dispatch policy for one plan's chunk tasks.

    Built by :class:`repro.engine.plan.Plan` from its
    :class:`~repro.engine.EngineConfig` (``schedule``,
    ``n_executor_devices``, ``max_attempts``, ``schedule_fallback``,
    ``fault_plan``); the distributed backend pins the pool to a single
    slot because its mesh already owns every device (shard_map is the
    parallelism there — the executor contributes only the chunk loop).
    See the module docstring for the scheduling and fault-recovery
    policies.

    :meth:`run` drives ``step(ctx, hi, lo, task) -> (hi, lo)`` over the
    task list, where ``ctx = place(device)`` is the backend's
    device-resident context (graph arrays + dyad stream; ``place(None)``
    must return the default-placement context unchanged — that keeps the
    static path free of extra transfers).  Dispatch counts land in
    ``stats["device_chunks"]`` (``{device index: chunks}``) — the
    occupancy signal :meth:`repro.serve.CensusService.stats` aggregates.
    """

    #: generic (non-device-loss) dispatch failures on one device before
    #: it is quarantined — provided at least one other device survives.
    QUARANTINE_AFTER = 2

    def __init__(self, config, stats: dict, *, n_devices: int = 1,
                 backend: str = "xla"):
        self.schedule = config.schedule
        self.depth = max(1, config.pipeline_depth)
        self.max_attempts = max(1, config.max_attempts)
        self.schedule_fallback = config.schedule_fallback
        self.backend = backend
        self.faults = resolve_faults(config.fault_plan)
        n = max(1, min(n_devices, len(jax.devices())))
        # a 1-slot pool keeps default placement (device=None): no
        # device_put, no behavior change vs the pre-executor engine.
        self.devices = list(jax.devices()[:n]) if n > 1 else [None]
        self.stats = stats
        self._flock = threading.Lock()
        self._suppress_device_loss = False

    @property
    def n_devices(self) -> int:
        """Pool width (1 = default-device in-order dispatch)."""
        return len(self.devices)

    def _bump(self, dev_index: int, count: int) -> None:
        dc = self.stats.setdefault("device_chunks", {})
        dc[dev_index] = dc.get(dev_index, 0) + count

    # -- fault bookkeeping (thread-safe; counters + bounded trace) -----------

    def _fault_stats(self) -> dict:
        return self.stats.setdefault(
            "faults", dict(chunk_failures=0, retries=0, device_losses=0,
                           quarantines=0, backend_fallbacks=0,
                           schedule_fallbacks=0))

    def _note(self, *event, **counters) -> None:
        """Record fault counters and one trace event under the lock."""
        with self._flock:
            fs = self._fault_stats()
            for k, v in counters.items():
                fs[k] = fs.get(k, 0) + v
            if event:
                trace = self.stats.setdefault("fault_events", [])
                if len(trace) < _MAX_EVENTS:
                    trace.append(event)

    # -- fault-aware single dispatch -----------------------------------------

    def _dispatch(self, ctx, hi, lo, task, step, dev_index, ordinal, attempt):
        """One dispatch attempt of ``task`` on pool device ``dev_index``,
        with injection checks from the resolved fault plan (skipped
        entirely — zero overhead — when no plan is active)."""
        f = self.faults
        if f is not None:
            if (not self._suppress_device_loss
                    and f.device_lost(dev_index, ordinal)):
                self._note("device_loss", dev_index, device_losses=1)
                raise DeviceLostError(
                    f"injected loss of pool device {dev_index} at dispatch "
                    f"ordinal {ordinal}")
            if f.runtime_fails(self.backend):
                self._note("runtime_failure", self.backend, task.start,
                           chunk_failures=1)
                raise InjectedFault(
                    f"injected {self.backend} runtime failure for chunk at "
                    f"dyad {task.start}")
            if f.chunk_fails(task.start, attempt):
                self._note("chunk_failure", task.start, attempt,
                           chunk_failures=1)
                raise InjectedFault(
                    f"injected failure for chunk at dyad {task.start} "
                    f"(attempt {attempt})")
            f.maybe_delay(task.start)
        return step(ctx, hi, lo, task)

    def _attempt(self, ctx, hi, lo, task, step, dev_index, ordinal):
        """Bounded-retry dispatch of one task on one device (the static
        path's recovery policy).  Chunk kernels are functional, so a
        failed attempt leaves (hi, lo) untouched and the eventual
        successful fold is bit-identical to a fault-free run."""
        failures: list = []
        for attempt in range(1, self.max_attempts + 1):
            try:
                return self._dispatch(ctx, hi, lo, task, step, dev_index,
                                      ordinal, attempt)
            except Exception as e:  # noqa: BLE001 — KeyboardInterrupt etc.
                # (BaseException) must still abort the run immediately.
                failures.append(e)
                if isinstance(e, DeviceLostError):
                    break  # the device is gone; retrying in place is futile
                if attempt < self.max_attempts:
                    self._note("retry", task.start, attempt, retries=1)
        err = ChunkRetryError(
            f"chunk [{task.start}, {task.end}) failed after "
            f"{len(failures)} attempt(s) on device {dev_index}",
            attempts=failures)
        raise err from failures[-1]

    def run(self, tasks, *, place, step, init):
        """Execute every task; returns the merged (hi, lo) accumulator.

        ``init`` is the run's starting accumulator (it already carries
        the per-run ``once`` contribution) on default placement; the
        result is safe to pass to :func:`_acc_fetch`.  Recovers from
        per-chunk failures (bounded retry / re-queue), quarantines
        failing pool devices, and — when the pool is exhausted under
        ``schedule_fallback=True`` — re-runs the whole task list on the
        ladder's static single-device rung.
        """
        tasks = list(tasks)
        if len(self.devices) == 1:
            try:
                return self._run_inorder(tasks, place, step, init)
            except ChunkRetryError as e:
                # a 1-wide dynamic pool whose only device died is an
                # exhausted pool: same ladder rung as the N-wide case.
                if (self.schedule == "dynamic" and self.schedule_fallback
                        and isinstance(e.__cause__, DeviceLostError)):
                    return self._run_fallback(tasks, place, step, init)
                raise
        try:
            return self._run_workqueue(tasks, place, step, init)
        except PoolExhaustedError:
            if not self.schedule_fallback:
                raise
            return self._run_fallback(tasks, place, step, init)

    def _run_fallback(self, tasks, place, step, init):
        """The dynamic→static degradation rung: re-run the full task
        list in-order on the primary device, with device-loss injection
        suppressed (the rung models re-attaching a fresh device).  The
        accumulator restarts from ``init`` — partial dynamic progress is
        discarded, keeping the result bit-identical to a clean run."""
        self._note("schedule_fallback", "dynamic->static",
                   schedule_fallbacks=1)
        self._suppress_device_loss = True
        try:
            return self._run_inorder(tasks, place, step, init)
        finally:
            self._suppress_device_loss = False

    # -- static: the pre-executor single-device loop + bounded retry ---------

    def _run_inorder(self, tasks, place, step, init):
        ctx = place(self.devices[0])
        hi, lo = init
        window: collections.deque = collections.deque()
        for ordinal, t in enumerate(tasks):
            hi, lo = self._attempt(ctx, hi, lo, t, step, 0, ordinal)
            # chunk + occupancy counters move together so the
            # sum(device_chunks) == chunks invariant holds even if a
            # later task exhausts its retries mid-run.
            self.stats["chunks"] += 1
            self._bump(0, 1)
            _throttle(window, hi, self.depth)
        return hi, lo

    # -- dynamic: worker thread per device, shared task queue ----------------

    def _run_workqueue(self, tasks, place, step, init):
        # queue entries are (task, attempt): a failed task re-queues with
        # attempt + 1 and any surviving worker may pick it up; a task
        # dropped by a *lost* device re-queues at the same attempt (the
        # device was at fault, not the chunk).
        queue: collections.deque = collections.deque((t, 1) for t in tasks)
        qlock = threading.Lock()
        accs: list = [None] * len(self.devices)
        counts = [0] * len(self.devices)
        fatal: list = []
        alive = set(range(len(self.devices)))
        failures = [0] * len(self.devices)

        def quarantine(i: int, reason: str) -> None:
            # callers hold qlock
            alive.discard(i)
            self._note("quarantine", i, reason, quarantines=1)
            if not alive and queue and not fatal:
                fatal.append(PoolExhaustedError(
                    f"all {len(self.devices)} pool devices lost or "
                    f"quarantined with {len(queue)} task(s) remaining"))

        def on_failure(i: int, t, attempt: int, e: Exception) -> None:
            # callers hold qlock
            if isinstance(e, DeviceLostError):
                queue.append((t, attempt))  # chunk not at fault
                quarantine(i, "device_loss")
                return
            failures[i] += 1
            if attempt >= self.max_attempts:
                err = ChunkRetryError(
                    f"chunk [{t.start}, {t.end}) failed after {attempt} "
                    f"attempt(s) across the device pool")
                err.__cause__ = e
                fatal.append(err)
                return
            self._note("retry", t.start, attempt, retries=1)
            queue.append((t, attempt + 1))
            if failures[i] >= self.QUARANTINE_AFTER and len(alive) > 1:
                quarantine(i, "repeated_failures")

        def worker(i: int, dev) -> None:
            # XLA execution releases the GIL, so worker threads overlap
            # on distinct devices; jit compiles this device's replica on
            # its first task and caches it for the rest of the run.
            acc = None
            try:
                try:
                    ctx = place(dev)
                    acc = jax.device_put((jnp.zeros_like(init[0]),
                                          jnp.zeros_like(init[1])), dev)
                except Exception:  # a device whose context cannot even be
                    # placed is dead on arrival: quarantine, don't abort.
                    with qlock:
                        quarantine(i, "placement_failure")
                    return
                window: collections.deque = collections.deque()
                ordinal = 0
                while True:
                    with qlock:
                        if not queue or fatal or i not in alive:
                            break
                        t, attempt = queue.popleft()
                    try:
                        hi, lo = self._dispatch(ctx, *acc, t, step, i,
                                                ordinal, attempt)
                    except Exception as e:
                        ordinal += 1
                        with qlock:
                            on_failure(i, t, attempt, e)
                        continue
                    ordinal += 1
                    acc = (hi, lo)
                    counts[i] += 1
                    _throttle(window, hi, self.depth)
            except BaseException as e:  # noqa: BLE001 — ANY escape must
                # surface in the caller's thread: a silently dead worker
                # would otherwise drop every chunk it had folded and the
                # merged run would under-count with no error raised.
                with qlock:
                    fatal.append(e)
            finally:
                accs[i] = acc

        threads = [threading.Thread(target=worker, args=(i, d), daemon=True)
                   for i, d in enumerate(self.devices)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if fatal:
            # PoolExhaustedError outranks secondary errors: run() turns it
            # into the static-fallback rung, which re-runs everything.
            pool_dead = [e for e in fatal
                         if isinstance(e, PoolExhaustedError)]
            if pool_dead:
                raise pool_dead[0]
            _raise_worker_errors(fatal)
        self.stats["chunks"] += len(tasks)
        for i, c in enumerate(counts):
            if c:
                self._bump(i, c)
        # merge worker accumulators on the primary device: exact integer
        # folds, so the result is independent of the task assignment.  A
        # quarantined worker's accumulator is still valid — only its
        # *successful* folds touched it — and merges like any other.
        hi, lo = init
        primary = self.devices[0]
        for acc in accs:
            if acc is None:
                continue
            hi_d, lo_d = jax.device_put(acc, primary)
            hi, lo = _merge_accs(hi, lo, hi_d, lo_d)
        return hi, lo

    # -- pinned: in-order dispatch of a pre-placed shard context -------------

    def _run_pinned_once(self, tasks, ctx, step, init):
        hi, lo = init
        window: collections.deque = collections.deque()
        for ordinal, t in enumerate(tasks):
            hi, lo = self._attempt(ctx, hi, lo, t, step, 0, ordinal)
            self.stats["chunks"] += 1
            self._bump(0, 1)
            _throttle(window, hi, self.depth)
        return hi, lo

    def run_pinned(self, tasks, *, ctx, step, init, rebuild=None):
        """In-order dispatch on the PRIMARY device with a pre-placed
        context — the partitioned engine's ``partition_mode="serial"``
        rung: the caller stages ``ctx`` exactly once per shard (the
        hoisted ``device_put`` — no per-worker re-staging) and one shard
        context is resident at a time.  Bounded per-chunk retry as on the
        static path; a lost primary device under ``schedule_fallback``
        re-runs this shard's tasks with device-loss injection suppressed
        (fresh-device semantics), re-staging the context via ``rebuild()``
        when provided.  The accumulator restarts from ``init`` on that
        rung — failed attempts never touched it, so recovered results
        stay bit-identical."""
        try:
            return self._run_pinned_once(tasks, ctx, step, init)
        except ChunkRetryError as e:
            if not (self.schedule_fallback
                    and isinstance(e.__cause__, DeviceLostError)):
                raise
            self._note("schedule_fallback", "pinned-rerun",
                       schedule_fallbacks=1)
            self._suppress_device_loss = True
            try:
                return self._run_pinned_once(
                    tasks, ctx if rebuild is None else rebuild(), step, init)
            finally:
                self._suppress_device_loss = False

    # -- sharded: concurrent multi-shard workqueue over the pool -------------

    def run_sharded(self, shard_tasks, *, place, step, init, pstats):
        """Concurrent shard residency: drive EVERY shard's tasks through
        the pool at once (``partition_mode="pool"``).

        ``shard_tasks`` is ``[(shard_id, [ChunkTask, ...]), ...]``; each
        shard is HOMED on one pool device (round-robin) and
        ``place(shard_id, device)`` returns its device-resident context —
        called exactly once per shard per run (the caller counts these as
        ``stats["partition"]["h2d_puts"]``), so shard arrays stay
        resident for the whole run instead of re-staging per worker or
        per chunk.  Each (device, shard) pair accumulates into its own
        hi/lo lane; lanes merge on the primary device via
        :func:`_merge_accs` — exact integer folds, so the merged totals
        are bit-identical to the serial path for ANY homing, interleave,
        or re-home history.  Fault policy extends the workqueue's: a
        failed chunk retries on its home, a lost/quarantined device
        **re-homes its shards onto survivors** (their queued tasks move,
        ``place`` re-stages the context on the new home, the dead
        device's already-folded lanes stay valid and merge normally, and
        ``pstats["rehomes"]`` counts the moves), and an exhausted pool
        under ``schedule_fallback`` re-runs everything in-order on the
        primary device from ``init``.  Per-shard wall-clock intervals
        land in ``pstats["shard_times"]`` — the raw material for the
        ``shard_overlap`` concurrency observable."""
        shard_tasks = [(s, list(ts)) for s, ts in shard_tasks]
        try:
            return self._run_sharded_queue(shard_tasks, place, step, init,
                                           pstats)
        except PoolExhaustedError:
            if not self.schedule_fallback:
                raise
            self._note("schedule_fallback", "dynamic->static",
                       schedule_fallbacks=1)
            self._suppress_device_loss = True
            try:
                hi, lo = init
                for s, ts in shard_tasks:
                    ctx = place(s, self.devices[0])
                    hi, lo = self._run_pinned_once(ts, ctx, step, (hi, lo))
                return hi, lo
            finally:
                self._suppress_device_loss = False

    def _run_sharded_queue(self, shard_tasks, place, step, init, pstats):
        t_base = time.perf_counter()
        times = pstats.setdefault("shard_times", {})
        if len(self.devices) == 1:
            # degenerate pool (static schedule or one visible device):
            # shards run in-order on the primary device — still exactly
            # one staging per shard, still exact accumulator chaining.
            hi, lo = init
            for s, ts in shard_tasks:
                ctx = place(s, self.devices[0])
                start = time.perf_counter() - t_base
                hi, lo = self.run_pinned(
                    ts, ctx=ctx, step=step, init=(hi, lo),
                    rebuild=lambda s=s: place(s, self.devices[0]))
                times[s] = dict(start=start,
                                end=time.perf_counter() - t_base,
                                tasks=len(ts), device=0)
            return hi, lo
        n = len(self.devices)
        home: dict = {}
        queues = [collections.deque() for _ in range(n)]
        by_dev: list = [[] for _ in range(n)]
        for k, (s, ts) in enumerate(shard_tasks):
            home[s] = k % n
            by_dev[k % n].append((s, ts))
        for i, lst in enumerate(by_dev):
            # interleave this device's shards round-robin so same-device
            # shards advance together (P > pool width still overlaps).
            iters = [iter(ts) for _, ts in lst]
            names = [s for s, _ in lst]
            while iters:
                keep_i, keep_n = [], []
                for s, it in zip(names, iters):
                    t = next(it, None)
                    if t is not None:
                        queues[i].append((s, t, 1))
                        keep_i.append(it)
                        keep_n.append(s)
                iters, names = keep_i, keep_n
        cond = threading.Condition()
        lanes: dict = {}   # (dev_index, shard) -> device (hi, lo) lane
        ctxs: dict = {}    # shard -> context on its CURRENT home device
        counts = [0] * n
        fatal: list = []
        alive = set(range(n))
        failures = [0] * n
        first: dict = {}
        last: dict = {}
        task_total = sum(len(ts) for _, ts in shard_tasks)
        # tasks not yet folded or failed: workers with an empty queue WAIT
        # on this (a re-home may hand them work later) instead of exiting
        # — an early exit would strand re-homed tasks and undercount.
        pending = [task_total]

        def rehome(i: int) -> None:
            # callers hold cond: device i is out — move its remaining
            # queue onto survivors and re-point its shards' homes; the
            # new home's worker re-places each context on first touch.
            moved = queues[i]
            queues[i] = collections.deque()
            if not alive:
                if moved and not fatal:
                    fatal.append(PoolExhaustedError(
                        f"all {n} pool devices lost or quarantined with "
                        f"{len(moved)} task(s) remaining"))
                cond.notify_all()
                return
            survivors = sorted(alive)
            assigned: dict = {}
            for s, t, a in moved:
                j = assigned.get(s)
                if j is None:
                    j = survivors[len(assigned) % len(survivors)]
                    assigned[s] = j
                    home[s] = j
                    ctxs.pop(s, None)
                    pstats["rehomes"] = pstats.get("rehomes", 0) + 1
                    self._note("shard_rehome", s, i, j)
                queues[j].append((s, t, a))
            cond.notify_all()

        def quarantine(i: int, reason: str) -> None:
            # callers hold cond
            alive.discard(i)
            self._note("quarantine", i, reason, quarantines=1)
            rehome(i)

        def on_failure(i: int, s, t, attempt: int, e: Exception) -> None:
            # callers hold cond
            if isinstance(e, DeviceLostError):
                queues[i].appendleft((s, t, attempt))  # chunk not at fault
                quarantine(i, "device_loss")
                return
            failures[i] += 1
            if attempt >= self.max_attempts:
                err = ChunkRetryError(
                    f"chunk [{t.start}, {t.end}) of shard {s} failed "
                    f"after {attempt} attempt(s)")
                err.__cause__ = e
                fatal.append(err)
                cond.notify_all()
                return
            self._note("retry", t.start, attempt, retries=1)
            queues[i].append((s, t, attempt + 1))
            if failures[i] >= self.QUARANTINE_AFTER and len(alive) > 1:
                quarantine(i, "repeated_failures")
            cond.notify_all()

        def worker(i: int, dev) -> None:
            window: collections.deque = collections.deque()
            ordinal = 0
            mine: set = set()
            try:
                while True:
                    with cond:
                        # an empty queue is not the end: wait while other
                        # devices still hold pending tasks — a loss there
                        # re-homes work onto this queue.
                        while (not fatal and i in alive and not queues[i]
                               and pending[0] > 0):
                            cond.wait(0.05)
                        if fatal or i not in alive or not queues[i]:
                            break
                        s, t, attempt = queues[i].popleft()
                        ctx = ctxs.get(s)
                        first.setdefault(s, time.perf_counter() - t_base)
                    if ctx is None:
                        try:
                            ctx = place(s, dev)
                        except Exception:
                            with cond:
                                queues[i].appendleft((s, t, attempt))
                                quarantine(i, "placement_failure")
                            break
                        with cond:
                            ctxs[s] = ctx
                    with cond:
                        lane = lanes.get((i, s))
                    if lane is None:
                        lane = jax.device_put((jnp.zeros_like(init[0]),
                                               jnp.zeros_like(init[1])), dev)
                    try:
                        hi, lo = self._dispatch(ctx, *lane, t, step, i,
                                                ordinal, attempt)
                    except Exception as e:
                        ordinal += 1
                        with cond:
                            on_failure(i, s, t, attempt, e)
                        continue
                    ordinal += 1
                    mine.add(s)
                    counts[i] += 1
                    with cond:
                        lanes[(i, s)] = (hi, lo)
                        pending[0] -= 1
                        if pending[0] <= 0:
                            cond.notify_all()
                    _throttle(window, hi, self.depth)
            except BaseException as e:  # noqa: BLE001 — see _run_workqueue
                with cond:
                    fatal.append(e)
                    cond.notify_all()
            finally:
                # block on this worker's lanes so the recorded end times
                # reflect COMPLETED device work, not just dispatch.
                for s in mine:
                    with cond:
                        lane = lanes.get((i, s))
                    if lane is not None:
                        try:
                            lane[0].block_until_ready()
                        except Exception:  # timing only — never fatal
                            pass
                    with cond:
                        last[s] = max(last.get(s, 0.0),
                                      time.perf_counter() - t_base)

        threads = [threading.Thread(target=worker, args=(i, d), daemon=True)
                   for i, d in enumerate(self.devices)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if fatal:
            pool_dead = [e for e in fatal
                         if isinstance(e, PoolExhaustedError)]
            if pool_dead:
                raise pool_dead[0]
            _raise_worker_errors(fatal)
        self.stats["chunks"] += task_total
        for i, c in enumerate(counts):
            if c:
                self._bump(i, c)
        for s, ts in shard_tasks:
            if s in first:
                times[s] = dict(start=first[s],
                                end=max(last.get(s, first[s]), first[s]),
                                tasks=len(ts), device=home[s])
        # merge every (device, shard) lane on the primary device: exact
        # integer folds — bit-identical for any homing or re-home history
        # (a quarantined device's lanes hold only successful folds).
        hi, lo = init
        primary = self.devices[0]
        for key in sorted(lanes):
            hi_d, lo_d = jax.device_put(lanes[key], primary)
            hi, lo = _merge_accs(hi, lo, hi_d, lo_d)
        return hi, lo
