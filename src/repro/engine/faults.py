"""Deterministic fault injection for the execution and serving layers.

The paper's workload is hours-long memory-bound traversals over an
accelerator pool — the regime where a failed kernel launch, a lost
device, or one poison graph in a batch is a matter of *when*, not *if*.
This module makes every such failure **reproducibly testable in CI**: a
:class:`FaultPlan` is a frozen, hashable description of which faults
fire where, and every decision is a pure function of the plan's seed and
the dispatch coordinates (chunk start offset, attempt number, pool
device index, dispatch ordinal).  No wall clocks, no runtime RNG state —
replaying a run under the same plan injects exactly the same faults, so
the executor's retry / quarantine / fallback machinery (see
:mod:`repro.engine.executor` and the degradation ladder in
:mod:`repro.engine.plan`) can be asserted against, not just hoped for.

Faults are threaded through two hooks:

  * ``EngineConfig(fault_plan=FaultPlan(...))`` — per-plan injection
    (the fault plan is part of the plan-cache key, so faulty and clean
    plans never share compiled state);
  * the ``REPRO_FAULT_PLAN`` environment variable — a JSON object of
    :class:`FaultPlan` fields applied to every config whose own
    ``fault_plan`` is ``None``.  This is the chaos-CI hook: the whole
    tier-1 suite runs under a standing plan of recoverable faults and
    must stay green (``.github/workflows/ci.yml`` job ``test-chaos``).
    A config that must stay fault-free under chaos CI passes an inert
    ``FaultPlan()`` explicitly, which overrides the environment.

Poison graphs are the one injection not keyed by coordinates:
:func:`poison` marks a live :class:`~repro.core.graph.CSRGraph` object
so any run (or vmapped batch) containing it raises — the tool for
testing the service's member-wise batch isolation.  The registry holds
weak references, so a poisoned graph un-poisons itself when collected.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
import weakref
from typing import Optional, Tuple

__all__ = ["DeviceLostError", "FaultPlan", "InjectedFault",
           "fault_plan_from_env", "is_poisoned", "poison", "resolve_faults",
           "unpoison"]

_BACKENDS = ("xla", "pallas", "distributed")
ENV_VAR = "REPRO_FAULT_PLAN"


class InjectedFault(RuntimeError):
    """A failure raised by the fault-injection harness (never by real
    hardware): an injected chunk/kernel failure, compile failure,
    mid-mutate failure, or poison-graph rejection.  Deliberately a plain
    ``RuntimeError`` subclass so recovery code paths cannot special-case
    injected faults away from real ones."""


class DeviceLostError(InjectedFault):
    """An injected *permanent* device loss: every dispatch on the lost
    pool device raises this, modeling a device that fell off the bus.
    The executor reacts by quarantining the device (its queued work is
    re-dispatched to survivors) rather than retrying in place."""


def _hash01(seed: int, *coords) -> float:
    """Deterministic uniform [0, 1) from (seed, coordinates) — a pure
    counter-based hash, so fault decisions never consume RNG state."""
    payload = repr((int(seed),) + coords).encode()
    digest = hashlib.blake2b(payload, digest_size=8).digest()
    return int.from_bytes(digest, "big") / 2.0 ** 64


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, deterministic schedule of injected faults.

    Frozen and hashable — it rides inside
    :class:`~repro.engine.EngineConfig` (and therefore the plan-cache
    key).  All-default construction is **inert**: no fault ever fires,
    and an inert plan explicitly passed to a config suppresses the
    ``REPRO_FAULT_PLAN`` environment plan (see :func:`resolve_faults`).

    Attributes:
        seed: hash seed — same seed, same coordinates, same faults.
        chunk_failure_rate: probability (per chunk, decided by
            ``hash(seed, chunk start)``) that a chunk's kernel dispatch
            raises :class:`InjectedFault`.  A selected chunk fails its
            first ``fail_attempts`` attempts and then succeeds, so with
            ``fail_attempts < EngineConfig.max_attempts`` every injected
            chunk failure is deterministically recoverable.
        fail_attempts: how many consecutive attempts of a selected chunk
            fail.  Set it at or above ``max_attempts`` to force retry
            exhaustion (and the degradation ladder) deterministically.
        device_loss: executor pool device indices that die
            (:class:`DeviceLostError` on every dispatch at or past
            ``device_loss_after``).  The static schedule's 1-slot pool is
            index 0; the ladder's static fallback rung runs with device
            loss suppressed — it models reconnecting on a fresh device.
        device_loss_after: per-device dispatch ordinal after which a
            ``device_loss`` device dies (0 = dead on arrival).
        compile_failure: backend names whose compiled-unit construction
            raises at plan-build time — the hook for testing the
            pallas→xla compile-fallback rung.
        runtime_failure: backend names where **every** chunk dispatch
            raises, exhausting retries — the hook for testing the
            pallas→xla runtime-fallback rung.
        mutate_failure_calls: 0-based ordinals of a plan's
            ``apply_delta`` applications that raise mid-mutate — the
            hook for testing session raw-bin restoration in the serve
            layer.
        slow_chunk_rate: probability (per chunk, same keying as
            ``chunk_failure_rate``) that a dispatch sleeps ``slow_s``
            seconds first — jitters worker interleavings without
            changing any result.
        slow_s: the injected slow-chunk delay in seconds.
    """

    seed: int = 0
    chunk_failure_rate: float = 0.0
    fail_attempts: int = 1
    device_loss: Tuple[int, ...] = ()
    device_loss_after: int = 0
    compile_failure: Tuple[str, ...] = ()
    runtime_failure: Tuple[str, ...] = ()
    mutate_failure_calls: Tuple[int, ...] = ()
    slow_chunk_rate: float = 0.0
    slow_s: float = 0.001

    def __post_init__(self):
        # normalize list-valued fields so the plan stays hashable (it is
        # part of the plan-cache key via EngineConfig.fault_plan)
        object.__setattr__(self, "device_loss",
                           tuple(int(d) for d in self.device_loss))
        object.__setattr__(self, "compile_failure",
                           tuple(str(b) for b in self.compile_failure))
        object.__setattr__(self, "runtime_failure",
                           tuple(str(b) for b in self.runtime_failure))
        object.__setattr__(self, "mutate_failure_calls",
                           tuple(int(c) for c in self.mutate_failure_calls))
        for name in ("chunk_failure_rate", "slow_chunk_rate"):
            r = float(getattr(self, name))
            if not 0.0 <= r <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {r}")
            object.__setattr__(self, name, r)
        if self.fail_attempts < 1:
            raise ValueError(
                f"fail_attempts must be >= 1 (got {self.fail_attempts}); a "
                "selected chunk fails that many consecutive attempts")
        if any(d < 0 for d in self.device_loss):
            raise ValueError(f"device_loss indices must be >= 0, got "
                             f"{self.device_loss}")
        if self.device_loss_after < 0:
            raise ValueError("device_loss_after must be >= 0")
        for field in ("compile_failure", "runtime_failure"):
            bad = [b for b in getattr(self, field) if b not in _BACKENDS]
            if bad:
                raise ValueError(f"{field} names unknown backends {bad}; "
                                 f"choose from {_BACKENDS}")
        if any(c < 0 for c in self.mutate_failure_calls):
            raise ValueError("mutate_failure_calls ordinals must be >= 0")
        if float(self.slow_s) < 0:
            raise ValueError("slow_s must be >= 0")
        object.__setattr__(self, "slow_s", float(self.slow_s))

    @property
    def is_inert(self) -> bool:
        """True when no fault can ever fire — the executor then skips
        injection checks entirely, keeping the fault-free warm path at
        its original cost."""
        return (self.chunk_failure_rate == 0.0 and not self.device_loss
                and not self.compile_failure and not self.runtime_failure
                and not self.mutate_failure_calls
                and self.slow_chunk_rate == 0.0)

    # -- decision points (all pure functions of seed + coordinates) ----------

    def chunk_fails(self, start: int, attempt: int) -> bool:
        """Does the chunk at dyad offset ``start`` fail this attempt?"""
        return (attempt <= self.fail_attempts
                and _hash01(self.seed, "chunk", int(start))
                < self.chunk_failure_rate)

    def device_lost(self, dev_index: int, ordinal: int) -> bool:
        """Is pool device ``dev_index`` dead at its ``ordinal``-th
        dispatch?"""
        return (dev_index in self.device_loss
                and ordinal >= self.device_loss_after)

    def compile_fails(self, backend: str) -> bool:
        """Does building ``backend``'s compiled unit fail?"""
        return backend in self.compile_failure

    def runtime_fails(self, backend: str) -> bool:
        """Does every chunk dispatch on ``backend`` fail?"""
        return backend in self.runtime_failure

    def mutate_fails(self, ordinal: int) -> bool:
        """Does the ``ordinal``-th (0-based) ``apply_delta`` application
        on a plan fail mid-mutate?"""
        return ordinal in self.mutate_failure_calls

    def maybe_delay(self, start: int) -> None:
        """Sleep ``slow_s`` if the chunk at ``start`` is a selected slow
        chunk.  Which chunks are slow is deterministic; the sleep only
        perturbs worker interleavings, never results."""
        if (self.slow_chunk_rate
                and _hash01(self.seed, "slow", int(start))
                < self.slow_chunk_rate):
            time.sleep(self.slow_s)


_ENV_SENTINEL = object()
_env_plan = _ENV_SENTINEL


def fault_plan_from_env() -> Optional[FaultPlan]:
    """The standing :class:`FaultPlan` from the ``REPRO_FAULT_PLAN``
    environment variable (a JSON object of FaultPlan fields), or ``None``
    when unset.  Parsed once per process — the chaos-CI hook must not pay
    JSON parsing per dispatch."""
    global _env_plan
    if _env_plan is _ENV_SENTINEL:
        raw = os.environ.get(ENV_VAR)
        if not raw:
            _env_plan = None
        else:
            try:
                _env_plan = FaultPlan(**json.loads(raw))
            except (TypeError, ValueError) as e:
                raise ValueError(
                    f"invalid {ENV_VAR} value {raw!r}: {e}") from e
    return _env_plan


def resolve_faults(fault_plan: Optional[FaultPlan]) -> Optional[FaultPlan]:
    """The active fault plan for a config: the config's own plan when
    set (``None`` if it is inert — an explicit inert plan is the opt-out
    under chaos CI), else the ``REPRO_FAULT_PLAN`` environment plan.
    Returns ``None`` when no fault can fire, which is the executor's
    signal to skip injection checks entirely."""
    plan = fault_plan if fault_plan is not None else fault_plan_from_env()
    return None if (plan is None or plan.is_inert) else plan


# -- poison graphs (the batch-isolation injection) ---------------------------

# id -> weakref: graphs hold jax arrays so they are weak-referenceable
# but NOT hashable, ruling out a WeakSet.  The id key is guarded by an
# identity check on lookup and a collection callback on the ref, so a
# recycled id can never mark an unrelated object poisoned.
_POISONED: dict = {}


def poison(graph) -> None:
    """Mark a live graph object as poisoned: any plan run (or vmapped
    batch) containing it raises :class:`InjectedFault`.  The serve layer
    must isolate the failure member-wise — peers in the same batch still
    complete.  Weakly referenced: collection un-poisons automatically."""
    key = id(graph)
    _POISONED[key] = weakref.ref(graph, lambda _r, _k=key: _POISONED.pop(_k, None))


def unpoison(graph) -> None:
    """Remove a graph from the poison registry (no-op if absent)."""
    _POISONED.pop(id(graph), None)


def is_poisoned(graph) -> bool:
    """Is this graph object currently poisoned?  Identity-based — a
    structurally equal copy is not poisoned."""
    ref = _POISONED.get(id(graph))
    return ref is not None and ref() is graph


def check_poisoned(graph) -> None:
    """Raise :class:`InjectedFault` if ``graph`` is poisoned (the hook
    the plan's run paths call on every admitted graph)."""
    if _POISONED and is_poisoned(graph):
        raise InjectedFault(
            f"injected poison graph (n={getattr(graph, 'n', '?')}, "
            f"m={getattr(graph, 'm', '?')}) — this request must fail "
            "without taking down its batch peers")
