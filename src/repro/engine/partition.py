"""Partitioned (sharded-CSR) execution: concurrent shard residency,
device-side halo exchange, and out-of-core spill.

This is the device-side half of the graph-partitioning subsystem
(:mod:`repro.core.partition` builds the layout): with
``EngineConfig(partitions=P)`` the census runs as P shard passes, each
over a **local CSR** — the full rows of one contiguous vertex range plus
its halo of remote neighbor rows — with the shard's owned span of the
canonical dyad stream.  ``EngineConfig(partition_mode=...)`` picks the
execution strategy (:func:`EngineConfig.resolve_partition_mode` defaults
it):

``"pool"`` (default on the single-device backends with a device pool)
  Every shard's context is staged ONCE onto its home pool device and
  stays resident for the whole run; all shards' chunk tasks drive the
  executor's sharded workqueue **concurrently**
  (:meth:`~repro.engine.executor.Executor.run_sharded`), interleaved
  across worker threads.  Halo idx blocks are not materialized on the
  host: each shard uploads only its ptr halves and OWNED idx blocks
  (1/P of the graph) and every halo block transfers device-to-device
  from the owner shard's resident rows (``jax.device_put`` peer copy).
  Per-device memory is bounded by the largest shard context while
  aggregate pool memory holds the whole graph — the Cray-XMT
  aggregate-memory posture on a device pool.

``"mesh"`` (default on the distributed backend)
  Waves of ``n_devices`` shards execute as ONE ``shard_map`` dispatch:
  each mesh device scans its own shard's local CSR and dyad slab,
  folding into a per-device hi/lo lane — no psum (int32 lanes could
  overflow); lanes land on the primary device and merge exactly.

``"serial"`` (default whenever ``spill`` is set)
  One shard context resident at a time — the out-of-core property.  The
  context is staged once per shard (hoisted out of every per-chunk and
  per-worker path) and dispatched in-order on the primary device
  (:meth:`~repro.engine.executor.Executor.run_pinned`); the ``spill=``
  knob additionally stages each shard's dyad list through memory-mapped
  scratch files so a dyad stream larger than host RAM completes (pair
  with :func:`repro.core.graph.from_edges_mmap` for a fully out-of-core
  graph).

Every mode reuses the plan's OWN machinery end to end — the same
host-side schedules the incremental path uses (:mod:`repro.engine.delta`),
the same compiled chunk unit (every shard is padded to ONE common shard
geometry, so all shards share a single trace per plan), the same
:class:`~repro.engine.executor.Executor` fault policy (bounded retry,
device quarantine and shard re-homing, the degradation ladder) — so
every composition property holds by construction.  The whole-graph
``once`` contribution is folded exactly once; per-shard hi/lo
accumulators merge through :func:`~repro.engine.executor._merge_accs`
(exact integer folds on the primary device, bit-identical for ANY
homing, interleave, or re-home history) and ONE :func:`_acc_fetch`
completes the run — bit-identical raw bins to the unpartitioned path for
every registered op, in the same single counted device→host sync.

Correctness rests on the ``GraphOp.delta_local`` locality contract (a
dyad's contribution reads only ``{u, v} ∪ N(u) ∪ N(v)``, all of which
the halo keeps as FULL rows); plans refuse ``partitions > 1`` with any
op that opts out.  The incremental path composes: a delta's affected
dyads group by owner shard and only the owning shards dispatch —
concurrently under ``"pool"`` (:func:`subset_partitioned`).

``plan.stats["partition"]`` records the layout and the concurrency /
staging observables: ``mode``, ``h2d_puts`` (host→device context
stagings — exactly one per non-empty shard on the fault-free pool and
serial paths), ``d2d_puts`` (device-to-device halo block transfers),
``halo_host_puts`` (host-gathered halo blocks for owners with no
resident context), ``max_shard_bytes`` (the per-device residency bound),
``shard_times`` (per-shard wall-clock intervals) and ``shard_overlap``
(fraction of busy wall-clock with ≥ 2 shards in flight — the
concurrency proof the benchmark pins).
"""
from __future__ import annotations

import contextlib
import functools
import math
import os
import shutil
import tempfile
import time
import weakref

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import CSRGraph, GraphArrays
from ..core.graph import next_pow2 as _next_pow2
from ..core.partition import (GraphPartition, _gather_rows, _host,
                              build_local_arrays, halo_by_owner, local_ptrs,
                              owned_idx, partition_graph, shard_dyads)
from .executor import ChunkTask, _acc_fetch, _merge_accs

__all__ = ["full_context_bytes", "plan_partition", "run_partitioned",
           "shard_context_bytes", "subset_partitioned"]


def plan_partition(plan, g: CSRGraph) -> GraphPartition:
    """The (plan, graph) partition layout, memoized with the same
    bounded-8 weakref discipline as the reorder memo: warm runs (and
    every step of a mutation stream) pay zero partitioning cost.
    Shard count is clamped to the vertex count; metadata only is
    retained — local CSRs are rebuilt per run, one shard at a time."""
    memo = plan._partition_memo
    hit = memo.get(id(g))
    if hit is not None and hit[0]() is g:
        return hit[1]
    part = partition_graph(g, min(plan.partitions, max(g.n, 1)))
    while len(memo) >= 8:
        memo.pop(next(iter(memo)))
    memo[id(g)] = (weakref.ref(g), part)
    return part


class _Geometry:
    """Common shard device geometry: every shard pads its local idx
    arrays and dyad span to these bounds, so one plan compiles ONE trace
    of its chunk unit for all shards (the whole point of bucketing)."""

    def __init__(self, plan, part: GraphPartition):
        self.m_out = min(plan.meta.m_out_bucket,
                         _next_pow2(max((s.m_out for s in part.shards),
                                        default=1)))
        self.m_nbr = min(plan.meta.m_nbr_bucket,
                         _next_pow2(max((s.m_nbr for s in part.shards),
                                        default=1)))
        chunk = plan.chunk
        d = max(1, part.max_dyads)
        self.pad = max(chunk, -(-d // chunk) * chunk)
        if plan.backend == "distributed":
            from .backends import chunk_l
            n_dev = math.prod(plan.mesh.devices.shape)
            cl = chunk_l(plan)
            per = -(-d // n_dev)
            self.slab_l = max(cl, -(-per // cl) * cl)
            # mesh mode: each device row holds one FULL shard dyad list
            self.mesh_l = max(cl, -(-d // cl) * cl)

    def runner_kwargs(self, plan) -> dict:
        if plan.backend == "distributed":
            return {"slab_l": self.slab_l}
        return {"pad": self.pad}


def _shard_arrays(plan, g: CSRGraph, shard, geom: _Geometry) -> GraphArrays:
    """Device arrays for one shard: full-length (vertex-indexed) ptr/deg
    arrays padded to the plan's ``n_bucket`` exactly like the full path,
    over idx arrays compacted to the common shard buckets.  Vertex ids
    stay GLOBAL — kernels are untouched; non-kept rows are empty (every
    probe of them misses, which no owned dyad's reads ever do)."""
    from .plan import _pad_to
    local = build_local_arrays(g, shard.lo, shard.hi, shard.halo)
    m = plan.meta
    arrays = GraphArrays(
        out_ptr=jnp.asarray(_pad_to(local.out_ptr, m.n_bucket + 1,
                                    local.out_ptr[-1])),
        out_idx=jnp.asarray(_pad_to(local.out_idx, geom.m_out, 0)),
        nbr_ptr=jnp.asarray(_pad_to(local.nbr_ptr, m.n_bucket + 1,
                                    local.nbr_ptr[-1])),
        nbr_idx=jnp.asarray(_pad_to(local.nbr_idx, geom.m_nbr, 0)),
        nbr_deg=jnp.asarray(_pad_to(local.nbr_deg, m.n_bucket, 0)),
    )
    if _census_in_csr(plan):
        # shard-local transpose CSR — complete for kept rows, because an
        # in-arc source of an endpoint is one of its neighbors (in-halo).
        from ..kernels import ops
        in_ptr, in_idx = ops.build_in_csr_device(arrays.out_ptr,
                                                 arrays.out_idx)
        arrays = arrays._replace(in_ptr=in_ptr, in_idx=in_idx)
    return arrays


def _census_in_csr(plan) -> bool:
    return (plan.backend == "pallas" and plan.device_path
            and "triad_census" in plan.layout.slices)


def _once_init(plan, g: CSRGraph):
    """The whole-graph ``once`` contribution (folded into the run's
    accumulator exactly once, never once per shard).  Once kernels are
    whole-graph functions by contract, so plans carrying one pay a
    single full padded-array upload here; the per-dyad streaming — the
    memory-bound part — still runs shard-local."""
    from .delta import _zeros
    if not plan.layout.has_once:
        return _zeros(plan)
    from .backends import _once_device
    arrays = plan.padded_arrays(g, with_in_csr=False)
    return _once_device(plan, *_zeros(plan), arrays, jnp.int32(g.n))


@contextlib.contextmanager
def _spill_scratch(spill):
    """Scratch directory for spilled dyad stages: ``None`` disables,
    ``True`` uses a fresh temp dir, a string roots the scratch under a
    caller-owned path.  Always removed afterwards — spill files are
    transient per-run state, never a cache."""
    if not spill:
        yield None
        return
    if isinstance(spill, str):
        os.makedirs(spill, exist_ok=True)
        d = tempfile.mkdtemp(prefix="repro-spill-", dir=spill)
    else:
        d = tempfile.mkdtemp(prefix="repro-spill-")
    try:
        yield d
    finally:
        shutil.rmtree(d, ignore_errors=True)


def _stage_spill(u: np.ndarray, v: np.ndarray, scratch: str, tag: str):
    """Move one shard's dyad list out of RAM into an ``.npy`` memmap and
    hand back lazy read-only views — downstream padding copies from disk
    and the in-RAM list is dropped immediately."""
    path = os.path.join(scratch, f"{tag}.npy")
    d = len(u)
    mm = np.lib.format.open_memmap(path, mode="w+", dtype=np.int32,
                                   shape=(2, max(d, 1)))
    mm[0, :d] = u
    mm[1, :d] = v
    mm.flush()
    del mm
    ro = np.load(path, mmap_mode="r")
    return ro[0, :d], ro[1, :d]


# ---------------------------------------------------------------------------
# observability helpers
# ---------------------------------------------------------------------------

def _bytes_for(plan, m_out: int, m_nbr: int, dyad_slots: int) -> int:
    """int32 bytes of one resident census context with the given idx and
    dyad-slot geometry: ptr/deg halves + idx arrays (+ transpose CSR on
    the pallas census path) + the dyad stream + the hi/lo lanes."""
    m = plan.meta
    b = 4 * (2 * (m.n_bucket + 1) + m.n_bucket)
    b += 4 * (m_out + m_nbr)
    if _census_in_csr(plan):
        b += 4 * ((m.n_bucket + 1) + m_out)
    b += 2 * 4 * dyad_slots
    b += 2 * 4 * plan.layout.total_bins
    return int(b)


def shard_context_bytes(plan, geom: _Geometry) -> int:
    """Per-device residency bound of ONE shard context — the
    ``stats["partition"]["max_shard_bytes"]`` observable the benchmark
    compares against :func:`full_context_bytes` to prove the ~P-fold
    per-device memory drop."""
    dyads = geom.mesh_l if plan.backend == "distributed" else geom.pad
    return _bytes_for(plan, geom.m_out, geom.m_nbr, dyads)


def full_context_bytes(plan) -> int:
    """Residency of the UNPARTITIONED device context under the same
    accounting — the ``partitions=1`` baseline for the memory claim."""
    m = plan.meta
    return _bytes_for(plan, m.m_out_bucket, m.m_nbr_bucket, plan.dyad_pad)


def _overlap_fraction(times: dict) -> float:
    """Fraction of busy wall-clock during which >= 2 shards were in
    flight — an interval sweep over the per-shard ``[start, end)``
    records.  0.0 for a serial (or single-shard) run, approaching
    ``(P-1)/P`` when P equal shards fully overlap."""
    ivs = [(t["start"], t["end"]) for t in times.values()
           if t["end"] > t["start"]]
    if not ivs:
        return 0.0
    events = sorted([(a, 1) for a, _ in ivs] + [(b, -1) for _, b in ivs])
    busy = multi = 0.0
    depth = 0
    prev = events[0][0]
    for x, d in events:
        if depth >= 1:
            busy += x - prev
        if depth >= 2:
            multi += x - prev
        depth += d
        prev = x
    return float(multi / busy) if busy > 0 else 0.0


# ---------------------------------------------------------------------------
# shared step closure + device-side halo exchange units
# ---------------------------------------------------------------------------

def _make_step(plan):
    """The per-chunk step closure over a ``(arrays, n, du, dv)`` shard
    context — identical to the subset runners' step, shared by the
    serial and pool drivers so every shard dispatch reuses the plan's
    compiled chunk unit."""
    if plan.backend == "pallas":
        cfg = plan.config
        interpret = cfg.resolve_interpret()
        block = cfg.resolve_block()
        chunk = max(block, (plan.chunk // block) * block)

        def step(ctx, hi, lo, t):
            a, nn, su, sv = ctx
            return plan._fn(a, nn, su, sv, jnp.int32(t.start),
                            jnp.int32(t.end), hi, lo, K=int(t.key),
                            chunk=chunk, block=block, interpret=interpret)
        return step

    def step(ctx, hi, lo, t):
        a, nn, su, sv = ctx
        return plan._fn(a, nn, su, sv, jnp.int32(t.end), jnp.int32(t.start),
                        hi, lo)
    return step


def _device_zeros(size: int, dev):
    return jax.device_put(jnp.zeros(size, jnp.int32), dev)


@functools.partial(jax.jit, static_argnames=("out_len",))
def _gather_block(ptr, idx, ids, n_ids, out_len: int):
    """Concatenated CSR rows of ``ids`` read from a shard's RESIDENT
    local arrays — the owner-side half of the device halo exchange.
    ``ids`` is pow2-padded (pad lanes repeat a valid id, masked by
    ``n_ids``); the result packs the rows back-to-back in id order —
    exactly the layout the requester's compacted idx block expects —
    with zero fill past the true total."""
    lane = jnp.arange(ids.shape[0], dtype=jnp.int32)
    starts = ptr[ids]
    counts = jnp.where(lane < n_ids, ptr[ids + 1] - starts, 0)
    cum = jnp.cumsum(counts)
    pos = jnp.arange(out_len, dtype=jnp.int32)
    row = jnp.searchsorted(cum, pos, side="right")
    row_c = jnp.clip(row, 0, ids.shape[0] - 1)
    base = jnp.where(row_c > 0, cum[jnp.maximum(row_c - 1, 0)], 0)
    src = starts[row_c] + (pos - base)
    vals = idx[jnp.clip(src, 0, idx.shape[0] - 1)]
    return jnp.where(pos < cum[-1], vals, 0).astype(jnp.int32)


@jax.jit
def _scatter_block(idx_arr, vals, start, n_valid):
    """Write ``vals[:n_valid]`` into ``idx_arr[start:start+n_valid]`` on
    device — the requester-side half of the exchange.  Pad lanes map to
    an out-of-bounds position and drop (never a clamped
    ``dynamic_update_slice``, which would corrupt the tail)."""
    lane = jnp.arange(vals.shape[0], dtype=jnp.int32)
    pos = jnp.where(lane < n_valid, start + lane, idx_arr.shape[0])
    return idx_arr.at[pos].set(vals, mode="drop")


# ---------------------------------------------------------------------------
# pool mode: concurrent shard residency across the device pool
# ---------------------------------------------------------------------------

def _stage_pool_shard(plan, g, shard, geom, u, v, dev):
    """Phase 1 of pool staging: ONE host→device put per shard carrying
    the ptr halves (vertex-count-sized), the OWNED idx blocks (1/P of
    the graph — owned rows occupy the contiguous span
    ``[ptr[lo], ptr[hi])`` of the compacted idx layout) and the padded
    dyad stream.  The idx arrays are zero-initialized on device and the
    owned block scattered in; halo blocks arrive in phase 2, peer-to-peer
    from their owners."""
    from .plan import _pad_to
    m = plan.meta
    out_ptr, nbr_ptr, nbr_deg = local_ptrs(g, shard.lo, shard.hi, shard.halo)
    own_out, own_nbr = owned_idx(g, shard.lo, shard.hi)
    du = np.zeros(geom.pad, np.int32)
    dv = np.ones(geom.pad, np.int32)
    du[: len(u)] = u
    dv[: len(v)] = v
    host = (_pad_to(out_ptr, m.n_bucket + 1, out_ptr[-1]),
            _pad_to(nbr_ptr, m.n_bucket + 1, nbr_ptr[-1]),
            _pad_to(nbr_deg, m.n_bucket, 0),
            _pad_to(own_out, _next_pow2(max(len(own_out), 1)), 0),
            _pad_to(own_nbr, _next_pow2(max(len(own_nbr), 1)), 0),
            np.int32(g.n), du, dv)
    (d_optr, d_nptr, d_deg, d_oblk, d_nblk,
     d_n, d_du, d_dv) = jax.device_put(host, dev)
    out_idx = _scatter_block(_device_zeros(geom.m_out, dev), d_oblk,
                             jnp.int32(int(out_ptr[shard.lo])),
                             jnp.int32(len(own_out)))
    nbr_idx = _scatter_block(_device_zeros(geom.m_nbr, dev), d_nblk,
                             jnp.int32(int(nbr_ptr[shard.lo])),
                             jnp.int32(len(own_nbr)))
    return dict(dev=dev, n=d_n, du=d_du, dv=d_dv,
                out_ptr=d_optr, nbr_ptr=d_nptr, nbr_deg=d_deg,
                out_idx=out_idx, nbr_idx=nbr_idx,
                host_out_ptr=out_ptr, host_nbr_ptr=nbr_ptr)


def _exchange_halos(plan, g, part, work, pstats):
    """Phase 2: route every (requester, owner) halo group of ids —
    contiguous both in the owner's owned span and in the requester's
    compacted layout — through an owner-device gather, a peer
    ``jax.device_put``, and a requester-device scatter.  Owners with no
    resident context (shards that own zero dyads) fall back to a
    host-side gather, counted separately as ``halo_host_puts``."""
    shards = {s.index: s for s in part.shards}
    for s, w in work.items():
        halo = shards[s].halo
        for owner, ids in halo_by_owner(part.cuts, halo):
            ow = work.get(owner)
            spans = {}
            for csr in ("out", "nbr"):
                hp = w[f"host_{csr}_ptr"]
                blk = int(hp[ids[0]])
                nv = int(hp[ids[-1] + 1]) - blk
                spans[csr] = (blk, nv)
            if ow is not None and ow["dev"] is not w["dev"]:
                pad_ids = np.full(_next_pow2(max(len(ids), 1)),
                                  ids[-1], np.int32)
                pad_ids[: len(ids)] = ids
                d_ids = jax.device_put(pad_ids, ow["dev"])
                n_ids = jnp.int32(len(ids))
                vals = tuple(
                    _gather_block(ow[f"{csr}_ptr"], ow[f"{csr}_idx"],
                                  d_ids, n_ids,
                                  out_len=_next_pow2(max(spans[csr][1], 1)))
                    for csr in ("out", "nbr"))
                vals = jax.device_put(vals, w["dev"])
                pstats["d2d_puts"] += 1
            elif ow is not None:
                # same-device owner (P > pool width): gather in place,
                # no transfer to count.
                pad_ids = np.full(_next_pow2(max(len(ids), 1)),
                                  ids[-1], np.int32)
                pad_ids[: len(ids)] = ids
                d_ids = jax.device_put(pad_ids, ow["dev"])
                n_ids = jnp.int32(len(ids))
                vals = tuple(
                    _gather_block(ow[f"{csr}_ptr"], ow[f"{csr}_idx"],
                                  d_ids, n_ids,
                                  out_len=_next_pow2(max(spans[csr][1], 1)))
                    for csr in ("out", "nbr"))
            else:
                # owner owns no dyads, so it was never staged: host rows
                # (identical to any resident copy) upload directly.
                ids64 = ids.astype(np.int64)
                host_vals = []
                for csr in ("out", "nbr"):
                    ptr = _host(getattr(g.arrays, f"{csr}_ptr"))
                    ptr = ptr[: g.n + 1].astype(np.int64)
                    idx = getattr(g.arrays, f"{csr}_idx")
                    rows = _gather_rows(ptr, idx, ids64).astype(np.int32)
                    pad = np.zeros(_next_pow2(max(len(rows), 1)), np.int32)
                    pad[: len(rows)] = rows
                    host_vals.append(pad)
                vals = jax.device_put(tuple(host_vals), w["dev"])
                pstats["halo_host_puts"] = pstats.get("halo_host_puts",
                                                      0) + 1
            for csr, mv in zip(("out", "nbr"), vals):
                blk, nv = spans[csr]
                w[f"{csr}_idx"] = _scatter_block(w[f"{csr}_idx"], mv,
                                                 jnp.int32(blk),
                                                 jnp.int32(nv))


def _finish_pool_context(plan, w):
    """Assemble one staged shard's executor context (and, on the pallas
    census path, build the shard-local transpose CSR on its home device
    from the now-complete out-CSR)."""
    arrays = GraphArrays(out_ptr=w["out_ptr"], out_idx=w["out_idx"],
                         nbr_ptr=w["nbr_ptr"], nbr_idx=w["nbr_idx"],
                         nbr_deg=w["nbr_deg"])
    if _census_in_csr(plan):
        from ..kernels import ops
        in_ptr, in_idx = ops.build_in_csr_device(w["out_ptr"], w["out_idx"])
        arrays = arrays._replace(in_ptr=in_ptr, in_idx=in_idx)
    return (arrays, w["n"], w["du"], w["dv"])


def _host_ctx(plan, g, shard, geom, u, v, dev):
    """Full host-side shard context build — the re-home / fallback path
    (the shard's resident device is gone, so its arrays rebuild from the
    host onto ``dev``).  ``u``/``v`` must already be in dispatch order
    (the pallas schedule reorders them once, up front)."""
    from .delta import _pad_dyad_list
    arrays = _shard_arrays(plan, g, shard, geom)
    du, dv = _pad_dyad_list(plan, u, v, geom.pad)
    ctx = (arrays, jnp.int32(g.n), du, dv)
    return jax.device_put(ctx, dev)


def _pool_pass(plan, g, part, geom, shard_lists, init, pstats):
    """Concurrent pool execution of ``shard_lists`` (``[(shard, u, v)]``)
    — shared by the full run and the pool-mode delta subset.  Stages
    every shard's context onto its round-robin home device (matching
    :meth:`Executor.run_sharded`'s homing, so every first placement is a
    resident hit), exchanges halos device-to-device, then drives all
    shards' tasks through the sharded workqueue at once."""
    from .delta import _pallas_subset_schedule, _subset_tasks
    devs = plan.executor.devices
    prep = []
    for shard, u, v in shard_lists:
        if plan.backend == "pallas":
            u, v, tasks, _c, _b, _i = _pallas_subset_schedule(plan, g, u, v)
        else:
            tasks = _subset_tasks(plan, g, u, v, plan.chunk)
        prep.append((shard, np.asarray(u, dtype=np.int32),
                     np.asarray(v, dtype=np.int32), tasks))
    by_id = {shard.index: (shard, u, v) for shard, u, v, _t in prep}
    work = {}
    for k, (shard, u, v, _t) in enumerate(prep):
        work[shard.index] = _stage_pool_shard(plan, g, shard, geom, u, v,
                                              devs[k % len(devs)])
        pstats["h2d_puts"] += 1
    _exchange_halos(plan, g, part, work, pstats)
    ctxs = {s: (w["dev"], _finish_pool_context(plan, w))
            for s, w in work.items()}
    step = _make_step(plan)

    def place(s, dev):
        hit = ctxs.get(s)
        if hit is not None and hit[0] is dev:
            return hit[1]
        # re-home (or the exhausted-pool pinned rung): the old residency
        # is unreachable, so the context rebuilds from the host.
        shard, u, v = by_id[s]
        pstats["h2d_puts"] += 1
        ctx = _host_ctx(plan, g, shard, geom, u, v, dev)
        ctxs[s] = (dev, ctx)
        return ctx

    return plan.executor.run_sharded(
        [(shard.index, ts) for shard, _u, _v, ts in prep],
        place=place, step=step, init=init, pstats=pstats)


# ---------------------------------------------------------------------------
# serial mode: one resident shard at a time (the out-of-core rung)
# ---------------------------------------------------------------------------

def _serial_pass(plan, g, part, geom, shard_lists, init, pstats):
    """Serial shard loop with hoisted staging: each shard's context is
    built and placed exactly ONCE (``h2d_puts`` pins it — never per
    chunk, never per worker) and dispatched in-order on the primary
    device; exact accumulator chaining keeps bit-identity."""
    times = pstats.setdefault("shard_times", {})
    t_base = time.perf_counter()
    total = init
    if plan.backend == "distributed":
        from .backends import chunk_l
        from .delta import _subset_distributed, _zeros
        cl = chunk_l(plan)
        for shard, u, v in shard_lists:
            arrays = _shard_arrays(plan, g, shard, geom)
            pstats["h2d_puts"] += 1
            start = time.perf_counter() - t_base
            hi, lo = _subset_distributed(plan, g, u, v, arrays=arrays,
                                         init=_zeros(plan),
                                         slab_l=geom.slab_l)
            total = _merge_accs(*total, hi, lo)
            times[shard.index] = dict(start=start,
                                      end=time.perf_counter() - t_base,
                                      tasks=geom.slab_l // cl, device=0)
        return total
    from .delta import (_pad_dyad_list, _pallas_subset_schedule,
                        _subset_tasks)
    step = _make_step(plan)
    for shard, u, v in shard_lists:
        if plan.backend == "pallas":
            u, v, tasks, _c, _b, _i = _pallas_subset_schedule(plan, g, u, v)
        else:
            tasks = _subset_tasks(plan, g, u, v, plan.chunk)

        def build(shard=shard, u=u, v=v):
            arrays = _shard_arrays(plan, g, shard, geom)
            du, dv = _pad_dyad_list(plan, u, v, geom.pad)
            return (arrays, jnp.int32(g.n), du, dv)

        ctx = build()
        pstats["h2d_puts"] += 1
        start = time.perf_counter() - t_base
        total = plan.executor.run_pinned(tasks, ctx=ctx, step=step,
                                         init=total, rebuild=build)
        times[shard.index] = dict(start=start,
                                  end=time.perf_counter() - t_base,
                                  tasks=len(tasks), device=0)
    return total


# ---------------------------------------------------------------------------
# mesh mode: waves of shards across the distributed mesh
# ---------------------------------------------------------------------------

def _mesh_unit(plan):
    """The mesh-partitioned chunk unit, built once per plan and memoized
    on ``plan._mesh_part_fn``: a ``shard_map`` where each mesh device
    scans ITS OWN shard's local CSR and dyad slab through the plan's
    fused batch kernel, folding into a per-device hi/lo lane.  No psum —
    per-device lo words can exceed the hi/lo carry bound if summed in
    int32 across the mesh — so the stacked ``(n_devices, n_bins)`` lanes
    return as-is and merge exactly on the primary device."""
    if plan._mesh_part_fn is not None:
        return plan._mesh_part_fn
    from jax.sharding import PartitionSpec as P

    from .. import compat
    from .executor import _acc_update
    mesh = plan.mesh
    axes = tuple(mesh.axis_names)
    batch = plan.config.batch
    batch_fn = plan.layout.batch_kernel()
    stats = plan.stats

    def device_pass(arrays, n, u, v, valid, hi, lo):
        stats["traces"] += 1
        local = jax.tree_util.tree_map(lambda x: x[0], arrays)
        u, v, valid = u[0], v[0], valid[0]
        steps = u.shape[0] // batch

        def step(carry, xs):
            h, l = carry
            uu, vv, va = xs
            return _acc_update(h, l, batch_fn(local, n, uu, vv, va)), None

        (h, l), _ = jax.lax.scan(
            step, (hi[0], lo[0]),
            (u.reshape(steps, batch), v.reshape(steps, batch),
             valid.reshape(steps, batch)))
        return h[None], l[None]

    sh = P(axes)
    unit = jax.jit(compat.shard_map(
        device_pass, mesh=mesh,
        in_specs=(sh, P(), sh, sh, sh, sh, sh),
        out_specs=(sh, sh)))
    plan._mesh_part_fn = unit
    return unit


def _mesh_pass(plan, g, part, geom, shard_lists, init, pstats):
    """Mesh execution: waves of ``n_devices`` shards, each wave ONE
    stacked upload and one task sweep through the executor (retry and
    fault injection apply per chunk, as everywhere).  Within a wave all
    resident shards advance in lockstep — full overlap; short waves pad
    with inert slots (empty rows, valid=False dyads) that contribute
    nothing."""
    from .backends import chunk_l
    from .plan import _pad_to
    n_dev = math.prod(plan.mesh.devices.shape)
    cl = chunk_l(plan)
    L = geom.mesh_l
    unit = _mesh_unit(plan)
    m = plan.meta
    bins = plan.layout.total_bins
    primary = plan.executor.devices[0]
    times = pstats.setdefault("shard_times", {})
    t_base = time.perf_counter()
    total = init
    tasks = [ChunkTask(s, s + cl, float(cl * n_dev))
             for s in range(0, L, cl)]
    for wstart in range(0, len(shard_lists), n_dev):
        wave = shard_lists[wstart:wstart + n_dev]
        s_optr = np.zeros((n_dev, m.n_bucket + 1), np.int32)
        s_oidx = np.zeros((n_dev, geom.m_out), np.int32)
        s_nptr = np.zeros((n_dev, m.n_bucket + 1), np.int32)
        s_nidx = np.zeros((n_dev, geom.m_nbr), np.int32)
        s_deg = np.zeros((n_dev, m.n_bucket), np.int32)
        su = np.zeros((n_dev, L), np.int32)
        sv = np.ones((n_dev, L), np.int32)
        sval = np.zeros((n_dev, L), bool)
        for d, (shard, u, v) in enumerate(wave):
            local = build_local_arrays(g, shard.lo, shard.hi, shard.halo)
            s_optr[d] = _pad_to(local.out_ptr, m.n_bucket + 1,
                                local.out_ptr[-1])
            s_oidx[d] = _pad_to(local.out_idx, geom.m_out, 0)
            s_nptr[d] = _pad_to(local.nbr_ptr, m.n_bucket + 1,
                                local.nbr_ptr[-1])
            s_nidx[d] = _pad_to(local.nbr_idx, geom.m_nbr, 0)
            s_deg[d] = _pad_to(local.nbr_deg, m.n_bucket, 0)
            su[d, : len(u)] = u
            sv[d, : len(v)] = v
            sval[d, : len(u)] = True
        arrays = GraphArrays(out_ptr=jnp.asarray(s_optr),
                             out_idx=jnp.asarray(s_oidx),
                             nbr_ptr=jnp.asarray(s_nptr),
                             nbr_idx=jnp.asarray(s_nidx),
                             nbr_deg=jnp.asarray(s_deg))
        pstats["h2d_puts"] += 1  # one stacked staging per wave
        n = jnp.int32(g.n)
        dsu, dsv, dsval = jnp.asarray(su), jnp.asarray(sv), jnp.asarray(sval)
        z = jnp.zeros((n_dev, bins), jnp.int32)

        def place(dev, ctx=(arrays, n, dsu, dsv, dsval)):
            return ctx

        def step(ctx, hi, lo, t):
            a, nn, qu, qv, qval = ctx
            cu = jax.lax.dynamic_slice(qu, (0, t.start), (n_dev, cl))
            cv = jax.lax.dynamic_slice(qv, (0, t.start), (n_dev, cl))
            cva = jax.lax.dynamic_slice(qval, (0, t.start), (n_dev, cl))
            return unit(a, nn, cu, cv, cva, hi, lo)

        w_start = time.perf_counter() - t_base
        hi_l, lo_l = plan.executor.run(tasks, place=place, step=step,
                                       init=(z, z))
        for d in range(len(wave)):
            hd, ld = jax.device_put((hi_l[d], lo_l[d]), primary)
            total = _merge_accs(*total, hd, ld)
        w_end = time.perf_counter() - t_base
        for d, (shard, _u, _v) in enumerate(wave):
            times[shard.index] = dict(start=w_start, end=w_end,
                                      tasks=len(tasks), device=d)
    return total


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def run_partitioned(plan, g: CSRGraph) -> np.ndarray:
    """The partitioned full pass — ``Plan._run_raw``'s ``partitions > 1``
    branch.  Dispatches the plan's resolved ``partition_mode`` (pool /
    mesh / serial — see the module docstring), with the executor's full
    retry/quarantine/fallback machinery inside every mode, exact
    accumulator merging across shards, ONE counted device→host sync.
    Records the layout, staging and concurrency observables in
    ``plan.stats["partition"]``."""
    if g.n_dyads == 0:  # full-run convention: all-zero bins, no sync
        return np.zeros(plan.layout.total_bins, dtype=np.int64)
    part = plan_partition(plan, g)
    geom = _Geometry(plan, part)
    mode = plan.partition_mode or "serial"
    spill = plan.config.resolve_spill()
    pstats = dict(partitions=part.parts,
                  mode=mode,
                  cuts=[int(c) for c in part.cuts],
                  shard_dyads=part.dyad_counts,
                  halo_sizes=part.halo_sizes,
                  spill=bool(spill),
                  h2d_puts=0, d2d_puts=0,
                  max_stage_bytes=0,
                  max_shard_bytes=shard_context_bytes(plan, geom),
                  stream_bytes=int(2 * 4 * g.n_dyads))
    init = _once_init(plan, g)
    with _spill_scratch(spill) as scratch:
        shard_lists = []
        for shard in part.shards:
            if shard.n_dyads == 0:
                continue
            u, v = shard_dyads(g, shard.lo, shard.hi)
            stage = int(u.nbytes + v.nbytes + 2 * 4 * geom.pad)
            pstats["max_stage_bytes"] = max(pstats["max_stage_bytes"],
                                            stage)
            if scratch is not None:
                u, v = _stage_spill(u, v, scratch, f"shard{shard.index}")
            shard_lists.append((shard, u, v))
        if not shard_lists:
            total = init
        elif mode == "pool":
            total = _pool_pass(plan, g, part, geom, shard_lists, init,
                               pstats)
        elif mode == "mesh":
            total = _mesh_pass(plan, g, part, geom, shard_lists, init,
                               pstats)
        else:
            total = _serial_pass(plan, g, part, geom, shard_lists, init,
                                 pstats)
    pstats["shard_overlap"] = _overlap_fraction(
        pstats.get("shard_times", {}))
    plan.stats["partition"] = pstats
    return _acc_fetch(plan, *total)


def subset_partitioned(plan, g: CSRGraph, u: np.ndarray, v: np.ndarray):
    """Partitioned subset pass (the delta path's runner for
    ``partitions > 1``): the affected dyads group by owner shard —
    ``searchsorted`` over the cuts — and only the owning shards build a
    local CSR and dispatch: concurrently through the pool under
    ``partition_mode="pool"``, one owner at a time otherwise (a delta
    touches FEW shards — mesh waves would run mostly empty).  Returns an
    on-device ``(hi, lo)`` pair like every subset runner (no sync;
    ``delta_correction`` owns the one fetch).
    ``stats["partition"]["delta_shards"]`` records how few shards the
    mutation actually touched."""
    from .delta import _SUBSET_RUNNERS, _zeros
    part = plan_partition(plan, g)
    geom = _Geometry(plan, part)
    init = (_once_init(plan, g) if g.n_dyads else _zeros(plan))
    if len(u) == 0 or g.n_dyads == 0:
        return init
    owner = (np.searchsorted(part.cuts, np.asarray(u, dtype=np.int64),
                             side="right") - 1)
    shard_lists = []
    for shard in part.shards:
        sel = owner == shard.index
        if sel.any():
            shard_lists.append((shard, u[sel], v[sel]))
    mode = plan.partition_mode or "serial"
    if mode == "pool" and shard_lists:
        # concurrent owner dispatch; staging/timing records go to a
        # local dict so the last FULL run's observables stay readable.
        sub = dict(h2d_puts=0, d2d_puts=0)
        total = _pool_pass(plan, g, part, geom, shard_lists, init, sub)
    else:
        runner = _SUBSET_RUNNERS[plan.backend]
        total = None
        for shard, su_, sv_ in shard_lists:
            arrays = _shard_arrays(plan, g, shard, geom)
            seed = init if total is None else _zeros(plan)
            hi, lo = runner(plan, g, su_, sv_, arrays=arrays, init=seed,
                            **geom.runner_kwargs(plan))
            total = ((hi, lo) if total is None
                     else _merge_accs(*total, hi, lo))
        if total is None:
            total = init
    pstats = plan.stats.setdefault("partition",
                                   dict(partitions=part.parts))
    pstats["delta_shards"] = len(shard_lists)
    return total
