"""Partitioned (sharded-CSR) execution: halo exchange + out-of-core spill.

This is the device-side half of the graph-partitioning subsystem
(:mod:`repro.core.partition` builds the layout): with
``EngineConfig(partitions=P)`` the census runs as P shard passes, each
over a **local CSR** — the full rows of one contiguous vertex range plus
its halo of remote neighbor rows — with the shard's owned span of the
canonical dyad stream.  Per-device memory is bounded by the LARGEST
shard context, not the graph; the ``spill=`` knob additionally stages
each shard's dyad list through memory-mapped scratch files so a dyad
stream larger than host RAM completes (pair with
:func:`repro.core.graph.from_edges_mmap` for a fully out-of-core graph).

Execution reuses the plan's OWN machinery end to end — the same
generalized subset runners the incremental path uses
(:mod:`repro.engine.delta`), the same compiled chunk unit (every shard
is padded to ONE common shard geometry, so all shards share a single
trace per plan), the same :class:`~repro.engine.executor.Executor`
dispatch (static or dynamic schedule, bounded retry, device quarantine,
the degradation ladder) — so every composition property holds by
construction.  The whole-graph ``once`` contribution is folded exactly
once, into the first shard's accumulator; per-shard hi/lo accumulators
chain through :func:`~repro.engine.executor._merge_accs` (exact integer
merges on the primary device) and ONE :func:`_acc_fetch` completes the
run — bit-identical raw bins to the unpartitioned path for every
registered op, in the same single counted device→host sync.

Correctness rests on the ``GraphOp.delta_local`` locality contract (a
dyad's contribution reads only ``{u, v} ∪ N(u) ∪ N(v)``, all of which
the halo keeps as FULL rows); plans refuse ``partitions > 1`` with any
op that opts out.  The incremental path composes: a delta's affected
dyads group by owner shard and only the owning shards rebuild and
dispatch (:func:`subset_partitioned`).
"""
from __future__ import annotations

import contextlib
import os
import shutil
import tempfile
import weakref

import jax.numpy as jnp
import numpy as np

from ..core.graph import CSRGraph, GraphArrays
from ..core.graph import next_pow2 as _next_pow2
from ..core.partition import (GraphPartition, build_local_arrays,
                              partition_graph, shard_dyads)
from .executor import _acc_fetch, _merge_accs

__all__ = ["plan_partition", "run_partitioned", "subset_partitioned"]


def plan_partition(plan, g: CSRGraph) -> GraphPartition:
    """The (plan, graph) partition layout, memoized with the same
    bounded-8 weakref discipline as the reorder memo: warm runs (and
    every step of a mutation stream) pay zero partitioning cost.
    Shard count is clamped to the vertex count; metadata only is
    retained — local CSRs are rebuilt per run, one shard at a time."""
    memo = plan._partition_memo
    hit = memo.get(id(g))
    if hit is not None and hit[0]() is g:
        return hit[1]
    part = partition_graph(g, min(plan.partitions, max(g.n, 1)))
    while len(memo) >= 8:
        memo.pop(next(iter(memo)))
    memo[id(g)] = (weakref.ref(g), part)
    return part


class _Geometry:
    """Common shard device geometry: every shard pads its local idx
    arrays and dyad span to these bounds, so one plan compiles ONE trace
    of its chunk unit for all shards (the whole point of bucketing)."""

    def __init__(self, plan, part: GraphPartition):
        self.m_out = min(plan.meta.m_out_bucket,
                         _next_pow2(max((s.m_out for s in part.shards),
                                        default=1)))
        self.m_nbr = min(plan.meta.m_nbr_bucket,
                         _next_pow2(max((s.m_nbr for s in part.shards),
                                        default=1)))
        chunk = plan.chunk
        d = max(1, part.max_dyads)
        self.pad = max(chunk, -(-d // chunk) * chunk)
        if plan.backend == "distributed":
            import math

            from .backends import chunk_l
            n_dev = math.prod(plan.mesh.devices.shape)
            cl = chunk_l(plan)
            per = -(-d // n_dev)
            self.slab_l = max(cl, -(-per // cl) * cl)

    def runner_kwargs(self, plan) -> dict:
        if plan.backend == "distributed":
            return {"slab_l": self.slab_l}
        return {"pad": self.pad}


def _shard_arrays(plan, g: CSRGraph, shard, geom: _Geometry) -> GraphArrays:
    """Device arrays for one shard: full-length (vertex-indexed) ptr/deg
    arrays padded to the plan's ``n_bucket`` exactly like the full path,
    over idx arrays compacted to the common shard buckets.  Vertex ids
    stay GLOBAL — kernels are untouched; non-kept rows are empty (every
    probe of them misses, which no owned dyad's reads ever do)."""
    from .plan import _pad_to
    local = build_local_arrays(g, shard.lo, shard.hi, shard.halo)
    m = plan.meta
    arrays = GraphArrays(
        out_ptr=jnp.asarray(_pad_to(local.out_ptr, m.n_bucket + 1,
                                    local.out_ptr[-1])),
        out_idx=jnp.asarray(_pad_to(local.out_idx, geom.m_out, 0)),
        nbr_ptr=jnp.asarray(_pad_to(local.nbr_ptr, m.n_bucket + 1,
                                    local.nbr_ptr[-1])),
        nbr_idx=jnp.asarray(_pad_to(local.nbr_idx, geom.m_nbr, 0)),
        nbr_deg=jnp.asarray(_pad_to(local.nbr_deg, m.n_bucket, 0)),
    )
    if (plan.backend == "pallas" and plan.device_path
            and "triad_census" in plan.layout.slices):
        # shard-local transpose CSR — complete for kept rows, because an
        # in-arc source of an endpoint is one of its neighbors (in-halo).
        from ..kernels import ops
        in_ptr, in_idx = ops.build_in_csr_device(arrays.out_ptr,
                                                 arrays.out_idx)
        arrays = arrays._replace(in_ptr=in_ptr, in_idx=in_idx)
    return arrays


def _once_init(plan, g: CSRGraph):
    """The whole-graph ``once`` contribution (folded into the FIRST
    dispatched shard's accumulator — exactly once per run).  Once
    kernels are whole-graph functions by contract, so plans carrying one
    pay a single full padded-array upload here; the per-dyad streaming —
    the memory-bound part — still runs shard-at-a-time."""
    from .delta import _zeros
    if not plan.layout.has_once:
        return _zeros(plan)
    from .backends import _once_device
    arrays = plan.padded_arrays(g, with_in_csr=False)
    return _once_device(plan, *_zeros(plan), arrays, jnp.int32(g.n))


@contextlib.contextmanager
def _spill_scratch(spill):
    """Scratch directory for spilled dyad stages: ``None`` disables,
    ``True`` uses a fresh temp dir, a string roots the scratch under a
    caller-owned path.  Always removed afterwards — spill files are
    transient per-run state, never a cache."""
    if not spill:
        yield None
        return
    if isinstance(spill, str):
        os.makedirs(spill, exist_ok=True)
        d = tempfile.mkdtemp(prefix="repro-spill-", dir=spill)
    else:
        d = tempfile.mkdtemp(prefix="repro-spill-")
    try:
        yield d
    finally:
        shutil.rmtree(d, ignore_errors=True)


def _stage_spill(u: np.ndarray, v: np.ndarray, scratch: str, tag: str):
    """Move one shard's dyad list out of RAM into an ``.npy`` memmap and
    hand back lazy read-only views — downstream padding copies from disk
    and the in-RAM list is dropped immediately."""
    path = os.path.join(scratch, f"{tag}.npy")
    d = len(u)
    mm = np.lib.format.open_memmap(path, mode="w+", dtype=np.int32,
                                   shape=(2, max(d, 1)))
    mm[0, :d] = u
    mm[1, :d] = v
    mm.flush()
    del mm
    ro = np.load(path, mmap_mode="r")
    return ro[0, :d], ro[1, :d]


def run_partitioned(plan, g: CSRGraph) -> np.ndarray:
    """The partitioned full pass — ``Plan._run_raw``'s ``partitions > 1``
    branch.  Serial over shards (one shard context resident at a time —
    the out-of-core property), the executor's full schedule/pool/fault
    machinery *within* each shard, exact accumulator chaining across
    shards, ONE counted device→host sync.  Records the layout and
    staging footprint in ``plan.stats["partition"]``."""
    from .delta import _SUBSET_RUNNERS, _zeros
    if g.n_dyads == 0:  # full-run convention: all-zero bins, no sync
        return np.zeros(plan.layout.total_bins, dtype=np.int64)
    part = plan_partition(plan, g)
    geom = _Geometry(plan, part)
    runner = _SUBSET_RUNNERS[plan.backend]
    spill = plan.config.resolve_spill()
    pstats = dict(partitions=part.parts,
                  cuts=[int(c) for c in part.cuts],
                  shard_dyads=part.dyad_counts,
                  halo_sizes=part.halo_sizes,
                  spill=bool(spill),
                  max_stage_bytes=0,
                  stream_bytes=int(2 * 4 * g.n_dyads))
    init = _once_init(plan, g)
    total = None
    with _spill_scratch(spill) as scratch:
        for shard in part.shards:
            if shard.n_dyads == 0:
                continue
            u, v = shard_dyads(g, shard.lo, shard.hi)
            stage = int(u.nbytes + v.nbytes + 2 * 4 * geom.pad)
            pstats["max_stage_bytes"] = max(pstats["max_stage_bytes"],
                                            stage)
            if scratch is not None:
                u, v = _stage_spill(u, v, scratch, f"shard{shard.index}")
            arrays = _shard_arrays(plan, g, shard, geom)
            seed = init if total is None else _zeros(plan)
            hi, lo = runner(plan, g, u, v, arrays=arrays, init=seed,
                            **geom.runner_kwargs(plan))
            total = ((hi, lo) if total is None
                     else _merge_accs(*total, hi, lo))
    if total is None:
        total = init
    plan.stats["partition"] = pstats
    return _acc_fetch(plan, *total)


def subset_partitioned(plan, g: CSRGraph, u: np.ndarray, v: np.ndarray):
    """Partitioned subset pass (the delta path's runner for
    ``partitions > 1``): the affected dyads group by owner shard —
    ``searchsorted`` over the cuts — and only the owning shards build a
    local CSR and dispatch.  Returns an on-device ``(hi, lo)`` pair like
    every subset runner (no sync; ``delta_correction`` owns the one
    fetch).  ``stats["partition"]["delta_shards"]`` records how few
    shards the mutation actually touched."""
    from .delta import _SUBSET_RUNNERS, _zeros
    part = plan_partition(plan, g)
    geom = _Geometry(plan, part)
    runner = _SUBSET_RUNNERS[plan.backend]
    init = (_once_init(plan, g) if g.n_dyads else _zeros(plan))
    if len(u) == 0 or g.n_dyads == 0:
        return init
    owner = (np.searchsorted(part.cuts, np.asarray(u, dtype=np.int64),
                             side="right") - 1)
    total = None
    touched = 0
    for shard in part.shards:
        sel = owner == shard.index
        if not sel.any():
            continue
        touched += 1
        arrays = _shard_arrays(plan, g, shard, geom)
        seed = init if total is None else _zeros(plan)
        hi, lo = runner(plan, g, u[sel], v[sel], arrays=arrays, init=seed,
                        **geom.runner_kwargs(plan))
        total = (hi, lo) if total is None else _merge_accs(*total, hi, lo)
    pstats = plan.stats.setdefault("partition", dict(partitions=part.parts))
    pstats["delta_shards"] = touched
    return init if total is None else total
