"""Engine configuration (the single front door's knob surface).

One frozen, hashable dataclass — :class:`EngineConfig` — covers every
execution knob for any set of :class:`~repro.engine.ops.GraphOp`
analytics: backend choice, batch/tile geometry, load balancing,
accumulator dtype, interpret mode, and the streaming chunk size.
:data:`CensusConfig` is the same class under its original census-era
name, kept so existing call sites (and pickles of the config) keep
working — aliasing rather than subclassing means wrapper-API and new-API
plans hash equal and share one plan-cache entry.  Hashability matters:
the config is one third of the plan-cache key (with the graph metadata
buckets and the op names).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .faults import FaultPlan

BACKENDS = ("xla", "pallas", "distributed", "auto")
SCHEDULES = ("static", "dynamic")
REORDERS = ("none", "degree", "bfs", "rcm")
PARTITION_MODES = ("serial", "pool", "mesh")

_ACC_DTYPES = {"int32": jnp.int32, "int64": jnp.int64, "float32": jnp.float32}


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static execution policy for a fused graph-analytic pass.

    Attributes:
        backend: ``"xla"`` (binary-search scan), ``"pallas"`` (degree-bucketed
            VMEM tile kernel), ``"distributed"`` (shard_map SPMD), or
            ``"auto"`` (resolved from the visible hardware at compile time).
        batch: dyads per scan step (xla/distributed backends).
        block: pallas kernel block (dyads per grid step).  ``None`` picks
            ``min(batch, 32)`` — the (block, K, K) membership-compare
            intermediate makes large blocks expensive.
        k: tile width override (candidate lanes per dyad).  ``None`` derives
            a power-of-two bucket from the graph's max degree so same-shape
            graphs share one compiled plan.
        buckets: degree-bucket tile widths for the pallas backend (the
            smallest bucket >= a dyad's degree need wins).  Validated at
            construction: non-empty, strictly increasing, all positive —
            an unsorted or non-positive bucket list used to fail silently
            deep in tile building.
        strategy / weight_model: task packing for the distributed backend
            (see :mod:`repro.core.balance`).
        acc_dtype: on-device partial-histogram dtype, as a string so the
            config stays hashable ("int32" | "int64" | "float32").
        interpret: pallas interpret mode; ``None`` = interpret off-TPU.
        chunk_dyads: streaming chunk size — dyads materialized on device per
            execution step.  ``None`` picks a bounded default.  The plan
            caps the chunk at the graph's dyad-count bucket so small graphs
            don't pad up to a full default chunk.  Every chunk has the same
            padded shape, so one trace serves any graph whose metadata
            buckets match (and graphs whose dyad tiles exceed device memory
            still run).
        device_accum: ``True`` (the default via ``None``) runs the
            device-resident pipeline: dyads are enumerated, bucketed and
            chunk-sliced on device, partial counts accumulate **on device**
            across chunks as an int32 hi/lo pair (no x64 requirement), and
            one device→host transfer completes the run — the paper's
            single end-of-run merge, on every backend (the pallas bucket
            schedule is derived host-side from the degree arrays, so it
            costs no control fetch).  ``False`` restores the synchronous
            baseline: host-side dyad enumeration, per-chunk upload, and a
            blocking per-chunk device→host transfer with host int64
            accumulation (kept runnable for benchmark comparison via
            ``benchmarks/run.py --sync-baseline``).
        pipeline_depth: max in-flight chunks per device in the
            device-resident path (double-buffering depth).  The dispatcher
            enqueues chunk ``k + depth`` while chunk ``k`` still computes,
            then applies backpressure (a non-transferring block) so device
            queue memory stays bounded.  ``1`` degenerates to lockstep
            dispatch; ``2`` (default) is classic double buffering.
        schedule: chunk scheduling policy — ``"static"`` (default) runs
            the in-order single-device loop, bit-identical to the
            pre-executor engine; ``"dynamic"`` carves the dyad stream
            into chunks of roughly equal *predicted* work (the
            :mod:`repro.core.balance` degree cost model — heavy-degree
            dyads get smaller chunks) and dispatches them to the
            executor's device pool with a work-queue policy, the jax
            analogue of the paper's OpenMP dynamic scheduling.  See
            :mod:`repro.engine.executor`.
        n_executor_devices: executor device-pool width for
            ``schedule="dynamic"`` (``None`` = every visible device;
            clamped to the visible count).  Ignored — normalized to 1 —
            under ``schedule="static"`` and on the distributed backend,
            whose mesh already owns every device.  Exercise multi-device
            pools on CPU via
            ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
        delta_threshold: incremental-census cost-model cutoff, in
            ``(0, 1]``.  ``Plan.apply_delta`` runs the affected-subset
            correction only while the mutation footprint (affected dyads
            over the larger dyad stream) stays at or below this
            fraction; above it the full pass is cheaper and runs
            instead.  The default ``0.5`` is the delta pass's break-even
            — it walks the affected set twice, once per graph version.
            ``1.0`` always prefers the delta path.
        max_attempts: bounded retry budget per chunk dispatch (>= 1).  A
            failed chunk is re-dispatched — on the static schedule in
            place, on the dynamic schedule re-queued onto surviving pool
            devices — up to this many total attempts before the run
            raises :class:`~repro.engine.executor.ChunkRetryError`.
            Chunk kernels are functional (a failed attempt never touches
            the accumulator), so recovered runs are bit-identical to
            fault-free runs and still cost one device→host sync.
        backend_fallback: enable the pallas→xla rung of the degradation
            ladder — a pallas compile or runtime failure demotes the
            plan to the xla backend (recorded in ``Plan.degradation``)
            instead of failing the run.  ``False`` re-raises.
        schedule_fallback: enable the dynamic→static rung — a dynamic
            schedule whose device pool is exhausted (every device lost
            or quarantined) re-runs the task list in-order on a single
            device instead of failing the run.  ``False`` re-raises
            :class:`~repro.engine.executor.PoolExhaustedError`.
        reorder: locality-aware vertex relabeling applied before chunk
            dispatch — ``"none"`` (default, no relabeling), ``"degree"``
            (hubs first), ``"bfs"`` (Gorder-style frontier order) or
            ``"rcm"`` (reverse Cuthill–McKee); see
            :mod:`repro.core.reorder`.  The permutation is computed
            host-side once per (plan, graph) and memoized, execution runs
            on the relabeled graph, and raw bins map back through the
            inverse permutation, so results stay bit-identical to
            ``"none"`` for every registered op on every backend and
            schedule — including through ``Plan.apply_delta``, whose
            deltas stay in original vertex ids.  Part of the cache key —
            reordered and plain plans never share compiled state.
        fault_plan: a deterministic
            :class:`~repro.engine.faults.FaultPlan` injected into this
            plan's dispatch paths (``None`` = inherit the
            ``REPRO_FAULT_PLAN`` environment plan if set; an explicitly
            inert ``FaultPlan()`` opts out even under the environment
            hook).  Part of the cache key — faulty and clean plans never
            share compiled state.
        partitions: number of contiguous vertex-range graph shards
            (``None``/``1`` = the unpartitioned single-device CSR).
            With ``partitions > 1`` the engine splits the CSR into
            owned-dyad-balanced vertex ranges, builds each shard a local
            CSR plus a halo of remote neighbor rows, and runs the census
            one shard context at a time — per-device memory is bounded by
            the LARGEST SHARD, not the graph, results stay bit-identical
            to the unpartitioned path for every registered op on every
            backend and schedule, and the run still costs ONE device→host
            sync (shard accumulators merge on the primary device).  See
            :mod:`repro.engine.partition`.  Requires the device-resident
            path (``device_accum`` must not be ``False``) and every op to
            honor the ``delta_local`` locality contract.  Part of the
            cache key.
        spill: out-of-core staging for partitioned runs — ``None``/
            ``False`` (default) stages each shard's dyad list in host
            RAM; ``True`` stages it through memory-mapped scratch files
            in a fresh temp directory (removed after the run); a string
            names the scratch directory to use.  With an mmap-backed
            graph (:func:`repro.core.graph.from_edges_mmap`) peak host
            RAM is one shard's staging buffer, so a dyad stream larger
            than memory completes — ``stats["partition"]`` reports the
            measured ``max_stage_bytes`` against the full
            ``stream_bytes``.  Only meaningful with ``partitions > 1``.
        partition_mode: shard residency policy for ``partitions > 1``
            (``None`` resolves per backend; rejected when
            ``partitions`` is ``None``/``1``).  ``"pool"`` — the
            xla/pallas default — places every shard's local CSR and
            hi/lo accumulator on a distinct executor-pool device
            SIMULTANEOUSLY (resident for the whole run, one counted
            host→device staging per shard), fills halos with a
            device-side exchange (owner shards serve their rows via
            ``jax.device_put`` peer transfers), and drives all shards
            through the executor workqueue at once — aggregate pool
            memory, not the largest single device, bounds graph size,
            and shards overlap in wall time
            (``stats["partition"]["shard_overlap"]``).  ``"serial"``
            runs one shard context at a time pinned to the primary
            device — the out-of-core mode, and the default whenever
            ``spill`` is set; peak device memory is ONE shard.  ``"mesh"`` — the
            distributed-backend default — stacks shard contexts along
            the mesh axis and runs waves of ``shard_map``, one shard
            per mesh device per wave.  ``"mesh"`` requires the
            distributed backend and ``"pool"`` everything but (the
            mesh already owns every device).  All three modes are
            bit-identical to ``partitions=1`` and cost ONE device→host
            sync.  Part of the cache key (normalized at compile).
    """

    backend: str = "auto"
    batch: int = 256
    block: Optional[int] = None
    k: Optional[int] = None
    buckets: Tuple[int, ...] = (32, 128, 512)
    strategy: str = "sorted_snake"
    weight_model: str = "canonical_uniform"
    acc_dtype: str = "int32"
    interpret: Optional[bool] = None
    chunk_dyads: Optional[int] = None
    device_accum: Optional[bool] = None
    pipeline_depth: int = 2
    schedule: str = "static"
    n_executor_devices: Optional[int] = None
    delta_threshold: float = 0.5
    max_attempts: int = 3
    backend_fallback: bool = True
    schedule_fallback: bool = True
    reorder: str = "none"
    fault_plan: Optional[FaultPlan] = None
    partitions: Optional[int] = None
    spill: "Optional[bool | str]" = None
    partition_mode: Optional[str] = None

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, "
                             f"got {self.backend!r}")
        if self.acc_dtype not in _ACC_DTYPES:
            raise ValueError(f"acc_dtype must be one of {tuple(_ACC_DTYPES)}")
        if self.batch < 1:
            raise ValueError("batch must be >= 1")
        if self.block is not None and self.block < 1:
            raise ValueError("block must be >= 1")
        # normalize so list-valued buckets still hash (the config is a
        # cache key), then validate the tile-width ladder up front.
        object.__setattr__(self, "buckets",
                           tuple(int(b) for b in self.buckets))
        if not self.buckets:
            raise ValueError("buckets must be non-empty")
        prev = 0
        for b in self.buckets:
            if b < 1:
                raise ValueError(f"buckets must be positive, got {b}")
            if b <= prev:
                raise ValueError("buckets must be strictly increasing, "
                                 f"got {self.buckets}")
            prev = b
        if self.chunk_dyads is not None and self.chunk_dyads < 1:
            raise ValueError(
                f"chunk_dyads must be >= 1 (got {self.chunk_dyads}); use "
                "None for the bounded default")
        if self.pipeline_depth < 1:
            raise ValueError(
                f"pipeline_depth must be >= 1 (got {self.pipeline_depth}); "
                "1 = lockstep dispatch, 2 = double buffering")
        if self.schedule not in SCHEDULES:
            raise ValueError(f"schedule must be one of {SCHEDULES}, "
                             f"got {self.schedule!r}")
        if self.n_executor_devices is not None and self.n_executor_devices < 1:
            raise ValueError(
                f"n_executor_devices must be >= 1 (got "
                f"{self.n_executor_devices}); use None for every visible "
                "device")
        if not (0.0 < float(self.delta_threshold) <= 1.0):
            raise ValueError(
                f"delta_threshold must be in (0, 1] (got "
                f"{self.delta_threshold}); it is the affected-dyad "
                "fraction above which apply_delta falls back to a full "
                "recompute — 1.0 always prefers the delta path")
        object.__setattr__(self, "delta_threshold",
                           float(self.delta_threshold))
        if self.max_attempts < 1:
            raise ValueError(
                f"max_attempts must be >= 1 (got {self.max_attempts}); it "
                "is the total dispatch budget per chunk — 1 disables retry")
        for flag in ("backend_fallback", "schedule_fallback"):
            if not isinstance(getattr(self, flag), bool):
                raise ValueError(
                    f"{flag} must be a bool (got "
                    f"{getattr(self, flag)!r}); it toggles one rung of "
                    "the degradation ladder")
        if self.reorder not in REORDERS:
            raise ValueError(
                f"reorder must be one of {REORDERS}, got {self.reorder!r}; "
                "'none' disables relabeling, 'degree' packs hubs first, "
                "'bfs' uses Gorder-style frontier order, 'rcm' is reverse "
                "Cuthill-McKee")
        if self.fault_plan is not None and not isinstance(self.fault_plan,
                                                          FaultPlan):
            raise ValueError(
                f"fault_plan must be a FaultPlan or None, got "
                f"{type(self.fault_plan).__name__}")
        if self.partitions is not None and (
                not isinstance(self.partitions, int)
                or isinstance(self.partitions, bool)
                or self.partitions < 1):
            raise ValueError(
                f"partitions must be an int >= 1 or None (got "
                f"{self.partitions!r}); it is the number of contiguous "
                "vertex-range graph shards — None/1 is the unpartitioned "
                "single-device CSR")
        if self.spill is not None and not isinstance(self.spill, (bool, str)):
            raise ValueError(
                f"spill must be None, a bool, or a scratch-directory path "
                f"(got {type(self.spill).__name__}); True stages shard "
                "dyad lists through memory-mapped temp files, a string "
                "names the scratch directory")
        if self.partition_mode is not None:
            if self.partition_mode not in PARTITION_MODES:
                raise ValueError(
                    f"partition_mode must be one of {PARTITION_MODES} or "
                    f"None, got {self.partition_mode!r}; 'pool' makes every "
                    "shard resident on a distinct executor-pool device "
                    "simultaneously (device-side halo exchange), 'serial' "
                    "runs one shard context at a time on the primary device "
                    "(the out-of-core mode), 'mesh' runs shard waves via "
                    "shard_map on the distributed backend's mesh")
            if self.partitions is None or self.partitions == 1:
                raise ValueError(
                    f"partition_mode={self.partition_mode!r} requires "
                    "partitions > 1 — an unpartitioned run has no shards "
                    "to place; set partitions or drop partition_mode")
        if (self.partitions is not None and self.partitions > 1
                and self.device_accum is False):
            raise ValueError(
                f"partitions={self.partitions} requires the "
                "device-resident path: the synchronous baseline "
                "(device_accum=False) has no on-device accumulator to "
                "merge shard results into in one sync — drop "
                "device_accum=False or set partitions=1")

    @property
    def acc_jnp_dtype(self):
        return _ACC_DTYPES[self.acc_dtype]

    def resolve_backend(self) -> str:
        """Pin ``"auto"`` to a concrete backend for the current process."""
        if self.backend != "auto":
            return self.backend
        if jax.default_backend() == "tpu":
            return "pallas"
        return "distributed" if len(jax.devices()) > 1 else "xla"

    def resolve_chunk(self) -> int:
        """Streaming chunk size, rounded up to a whole number of batches."""
        c = self.chunk_dyads if self.chunk_dyads is not None else 8192
        return max(self.batch, ((c + self.batch - 1) // self.batch) * self.batch)

    def resolve_device_accum(self) -> bool:
        """Device-resident pipeline on/off; ``None`` means on."""
        return True if self.device_accum is None else self.device_accum

    def resolve_executor_devices(self) -> int:
        """Executor pool width for the current process: 1 under the
        static schedule, else ``n_executor_devices`` (``None`` = all)
        clamped to the visible device count."""
        if self.schedule != "dynamic":
            return 1
        n = (self.n_executor_devices if self.n_executor_devices is not None
             else len(jax.devices()))
        return max(1, min(n, len(jax.devices())))

    def resolve_partitions(self) -> int:
        """Graph shard count; ``None`` means unpartitioned (1)."""
        return 1 if self.partitions is None else int(self.partitions)

    def resolve_partition_mode(self, backend: "Optional[str]" = None) -> "Optional[str]":
        """Shard residency mode for the resolved backend: ``None`` for
        unpartitioned plans, the explicit mode when set, ``"serial"``
        when ``spill`` is active (out-of-core staging promises ONE
        resident shard — concurrent residency would break the bounded
        staging peak), else ``"mesh"`` on the distributed backend (whose
        mesh owns every device) and ``"pool"`` everywhere else.
        ``compile()`` normalizes the config through this, so ``None``
        and the mode it resolves to share one plan-cache entry."""
        if self.resolve_partitions() == 1:
            return None
        if self.partition_mode is not None:
            return self.partition_mode
        if self.resolve_spill():
            return "serial"
        backend = backend if backend is not None else self.resolve_backend()
        return "mesh" if backend == "distributed" else "pool"

    def resolve_spill(self) -> "Optional[bool | str]":
        """Spill policy with the inert ``False`` normalized to ``None``
        (so off-by-default and explicitly-off configs share one plan)."""
        return None if self.spill is False else self.spill

    def resolve_interpret(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return jax.default_backend() != "tpu"

    def resolve_block(self) -> int:
        return self.block if self.block is not None else min(self.batch, 32)


#: Census-era name for :class:`EngineConfig` — the same class (not a
#: subclass), so wrapper-API and new-API configs compare and hash equal.
CensusConfig = EngineConfig
