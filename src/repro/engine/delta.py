"""Incremental delta census: the affected-subset pass + exact correction.

:func:`delta_correction` turns a :class:`~repro.core.delta.GraphDelta`
into the exact int64 correction vector for a plan's cached raw bins:

    raw(new) == raw(old) + delta_correction(plan, g_old, g_new, delta)

bit for bit, for every registered :class:`~repro.engine.ops.GraphOp`, on
every backend.  The machinery is the plan's OWN streaming pipeline —
same compiled chunk unit (``plan._fn``), same
:class:`~repro.engine.executor.Executor` dispatch (static or dynamic
schedule, same device pool), same int32 hi/lo accumulator discipline —
restricted to the affected canonical dyads
(:func:`repro.core.delta.affected_dyads`) instead of the full stream.
Two subset passes run entirely on device (old graph's affected dyads
into one zero-initialized accumulator, new graph's into another, per-run
``once`` contributions folded into each like any full run), their
normalized difference is computed on device (:func:`_acc_diff` —
arithmetic-shift carries make the hi/lo form exact for negative totals),
and ONE device→host transfer fetches the correction — a delta
application costs exactly the one counted sync a full run costs, on work
proportional to the mutation's footprint.

Why subtraction is exact: every kernel is pure integer arithmetic over
the dyad's local structure, so an unaffected dyad contributes the same
value to both graphs and cancels without ever being computed; the
affected dyads are re-evaluated on both graphs and their old
contribution is subtracted exactly (``(hi, lo)`` with ``hi`` possibly
negative still packs to the exact int64 — arithmetic right-shift
normalization keeps ``0 <= lo < 2**30``).

The entry point users see is :meth:`repro.engine.Plan.apply_delta`,
which adds the cost-model fallback (``EngineConfig.delta_threshold``)
and returns a :class:`DeltaResult`.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import balance
from ..core.delta import GraphDelta, affected_dyads, apply_delta_csr
from ..core.graph import CSRGraph
from .executor import _ACC_SHIFT, ChunkTask, _acc_fetch
from .faults import InjectedFault, resolve_faults

__all__ = ["DeltaResult", "delta_correction"]


class DeltaResult(NamedTuple):
    """Outcome of one :meth:`repro.engine.Plan.apply_delta` application.

    ``graph`` is the mutated :class:`~repro.core.graph.CSRGraph`, ``raw``
    the updated fused int64 bins (pass both back into the next
    ``apply_delta`` to keep streaming), ``results`` the per-op finalized
    results for the new graph (identical to ``plan.run(graph)``),
    ``mode`` is ``"delta"`` (affected-subset correction) or ``"full"``
    (fallback recompute), and ``affected_fraction`` the footprint that
    drove the choice — affected dyads over the larger of the two dyad
    streams."""

    graph: CSRGraph
    raw: np.ndarray
    results: dict
    mode: str
    affected_fraction: float


@jax.jit
def _acc_diff(hi_n, lo_n, hi_o, lo_o):
    """Normalized hi/lo difference (new minus old), on device.

    Both inputs satisfy ``0 <= lo < 2**30``; the raw difference's lo word
    lies in ``(-2**30, 2**30)`` so the arithmetic-shift carry is in
    ``{-1, 0}`` and the result again satisfies the invariant, with ``hi``
    carrying the (possibly negative) sign — ``(hi << 30) + lo`` is the
    exact integer difference."""
    lo = lo_n - lo_o
    carry = lo >> _ACC_SHIFT
    return hi_n - hi_o + carry, lo - (carry << _ACC_SHIFT)


def affected_fraction(g_old: CSRGraph, g_new: CSRGraph,
                      n_old: int, n_new: int) -> float:
    """Mutation footprint: affected dyads over the larger dyad stream.

    The delta pass walks the affected set twice (old + new graph), so its
    break-even against one full pass sits near 0.5 — the default
    ``EngineConfig.delta_threshold``."""
    denom = max(g_old.n_dyads, g_new.n_dyads, 1)
    return max(n_old, n_new) / denom


def _pad_dyad_list(plan, u: np.ndarray, v: np.ndarray, pad=None):
    """Affected dyads padded to the plan's device dyad-list shape.

    The compiled chunk units were traced with ``(dyad_pad,)`` dyad
    streams; handing them the same shape means the subset pass reuses the
    full pass's executables with zero retraces.  Padding entries are the
    inert ``(0, 1)`` dyad, never covered by any task span.  ``pad``
    overrides the target length — the partitioned engine pads every
    shard's dyad span to ONE common length so all shards share a single
    trace of the chunk unit."""
    pad = plan.dyad_pad if pad is None else int(pad)
    du = np.zeros(pad, dtype=np.int32)
    dv = np.ones(pad, dtype=np.int32)
    du[: len(u)] = u
    dv[: len(v)] = v
    return jnp.asarray(du), jnp.asarray(dv)


def _subset_tasks(plan, g: CSRGraph, u: np.ndarray, v: np.ndarray,
                  chunk: int) -> "list[ChunkTask]":
    """Chunk schedule over the affected list ``[0, len(u))`` — the
    fixed-size grid under the static schedule, cost-model boundaries
    (per-dyad degree weights, as in the full pass) under dynamic."""
    D = len(u)
    if plan.config.schedule == "dynamic" and D:
        w = balance.dyad_weights(g, u, v, plan.config.weight_model)
        bounds = balance.chunk_bounds_by_cost(w, chunk)
        cum = np.concatenate([[0.0], np.cumsum(w, dtype=np.float64)])
        return [ChunkTask(int(a), int(b), float(cum[b] - cum[a]))
                for a, b in zip(bounds[:-1], bounds[1:])]
    return [ChunkTask(s, min(s + chunk, D), float(min(s + chunk, D) - s))
            for s in range(0, D, chunk)]


def _zeros(plan):
    z = jnp.zeros(plan.layout.total_bins, jnp.int32)
    return z, z


def _subset_xla(plan, g: CSRGraph, u: np.ndarray, v: np.ndarray, *,
                arrays=None, init=None, pad=None):
    """xla subset pass -> (hi, lo): once contribution + affected chunks.

    The keyword overrides are the partitioned engine's hooks
    (:mod:`repro.engine.partition`): ``arrays`` substitutes a shard-local
    CSR for the full padded arrays, ``init`` a pre-folded accumulator for
    the per-run once fold (so the whole-graph once contribution lands
    exactly once across shards, not once per shard), and ``pad`` a common
    shard dyad-list length."""
    from .backends import _once_device

    if g.n_dyads == 0:  # match the full-run convention: all-zero raw bins
        return _zeros(plan) if init is None else init
    if arrays is None:
        arrays = plan.padded_arrays(g)
    n = jnp.int32(g.n)
    du, dv = _pad_dyad_list(plan, u, v, pad)
    if init is None:
        init = _once_device(plan, *_zeros(plan), arrays, n)

    def place(dev):
        ctx = (arrays, n, du, dv)
        return ctx if dev is None else jax.device_put(ctx, dev)

    def step(ctx, hi, lo, t):
        a, nn, su, sv = ctx
        return plan._fn(a, nn, su, sv, jnp.int32(t.end), jnp.int32(t.start),
                        hi, lo)

    return plan.executor.run(_subset_tasks(plan, g, u, v, plan.chunk),
                             place=place, step=step, init=init)


def _subset_distributed(plan, g: CSRGraph, u: np.ndarray, v: np.ndarray, *,
                        arrays=None, init=None, slab_l=None):
    """distributed subset pass: affected dyads dealt round-robin into the
    ``(n_devices, L)`` slab layout the shard_map unit was traced for.

    ``arrays``/``init`` as in :func:`_subset_xla`; ``slab_l`` pins the
    per-device slab length so every shard of a partitioned run shares one
    trace (excess slab slots carry the validity-masked inert dyad)."""
    from .backends import _once_device, chunk_l

    if g.n_dyads == 0:
        return _zeros(plan) if init is None else init
    n_dev = math.prod(plan.mesh.devices.shape)
    cl = chunk_l(plan)
    D = len(u)
    if slab_l is None:
        # per-device slab: ceil(D / n_dev), rounded up to whole chunks
        per = -(-max(D, 1) // n_dev)
        L = max(cl, -(-per // cl) * cl)
    else:
        L = int(slab_l)
    tu = np.zeros((n_dev, L), dtype=np.int32)
    tv = np.ones((n_dev, L), dtype=np.int32)
    tval = np.zeros((n_dev, L), dtype=bool)
    r = np.arange(D)
    tu[r % n_dev, r // n_dev] = u
    tv[r % n_dev, r // n_dev] = v
    tval[r % n_dev, r // n_dev] = True
    if arrays is None:
        arrays = plan.padded_arrays(g)
    n = jnp.int32(g.n)
    dtu, dtv, dtval = jnp.asarray(tu), jnp.asarray(tv), jnp.asarray(tval)
    if init is None:
        init = _once_device(plan, *_zeros(plan), arrays, n)

    def place(dev):
        return (arrays, n, dtu, dtv, dtval)

    def step(ctx, hi, lo, t):
        a, nn, qu, qv, qval = ctx
        su = jax.lax.dynamic_slice(qu, (0, t.start), (n_dev, cl))
        sv = jax.lax.dynamic_slice(qv, (0, t.start), (n_dev, cl))
        sva = jax.lax.dynamic_slice(qval, (0, t.start), (n_dev, cl))
        return plan._fn(a, nn, su, sv, sva, hi, lo)

    tasks = [ChunkTask(s, s + cl, float(cl * n_dev))
             for s in range(0, L, cl)]
    return plan.executor.run(tasks, place=place, step=step, init=init)


def _pallas_subset_schedule(plan, g: CSRGraph, u: np.ndarray, v: np.ndarray):
    """Host-side (bucket, need) schedule for a pallas pass over the dyad
    sublist ``(u, v)`` — the subset mirror of the full pass's device sort,
    shared by the subset runner below and the partitioned drivers
    (:mod:`repro.engine.partition`), which must upload the device dyad
    list in the SAME order the task spans index into.

    Returns ``(u, v, tasks, chunk, block, interpret)`` with ``u``/``v``
    REORDERED into bucket-sorted order: every :class:`ChunkTask` carries
    the ``K`` specialization its span compiles against, so each dispatch
    hits an already-compiled tile kernel."""
    cfg = plan.config
    interpret = cfg.resolve_interpret()
    block = cfg.resolve_block()
    chunk = max(block, (plan.chunk // block) * block)
    kmax = max(plan.meta.k, 1)
    ks = tuple(sorted({min(max(int(k), 1), kmax)
                       for k in cfg.buckets} | {kmax}))
    census_needed = "triad_census" in plan.layout.slices
    D = len(u)
    if census_needed and D:
        deg = np.asarray(g.arrays.nbr_deg)
        out_deg = np.diff(np.asarray(g.arrays.out_ptr)[: g.n + 1])
        need = np.maximum(np.maximum(deg[u], deg[v]),
                          np.maximum(out_deg[u], out_deg[v])).astype(np.int64)
        ks_arr = np.asarray(ks, dtype=np.int64)
        b = (need[:, None] > ks_arr[None, :]).sum(1)
        order = np.lexsort((need, b))
        u, v, need, b = u[order], v[order], need[order], b[order]
        counts = np.bincount(b, minlength=len(ks))[: len(ks)]
        dynamic = cfg.schedule == "dynamic"
        if dynamic:
            cum = np.concatenate([[0.0], np.cumsum(need, dtype=np.float64)])
            target = cum[-1] / max(1, -(-D // chunk))
        tasks: list = []
        offset = 0
        for i, K in enumerate(ks):
            c = int(counts[i])
            if dynamic and c:
                bounds = offset + balance.chunk_bounds_by_cost(
                    need[offset:offset + c], chunk, target=target)
                tasks += [ChunkTask(int(a), int(e), float(cum[e] - cum[a]), K)
                          for a, e in zip(bounds[:-1], bounds[1:])]
            else:
                tasks += [ChunkTask(s, offset + c,
                                    float(K * min(chunk, offset + c - s)), K)
                          for s in range(offset, offset + c, chunk)]
            offset += c
    else:
        tasks = [t._replace(key=kmax)
                 for t in _subset_tasks(plan, g, u, v, chunk)]
    return u, v, tasks, chunk, block, interpret


def _subset_pallas(plan, g: CSRGraph, u: np.ndarray, v: np.ndarray, *,
                   arrays=None, init=None, pad=None):
    """pallas subset pass: host-side (bucket, need) sort of the affected
    dyads mirrors the full pass's device sort, so every task dispatches an
    already-compiled ``K`` specialization of the tile kernel.

    ``arrays``/``init``/``pad`` as in :func:`_subset_xla`; an ``arrays``
    override must already carry the transpose CSR when the plan runs the
    census tile kernel (the partitioned engine builds it per shard —
    shard-local in-rows are complete because every in-arc source of a
    kept endpoint is one of its neighbors, hence in the halo)."""
    from .backends import _once_device

    if g.n_dyads == 0:
        return _zeros(plan) if init is None else init
    census_needed = "triad_census" in plan.layout.slices
    if arrays is None:
        arrays = plan.padded_arrays(g, with_in_csr=census_needed)
    n = jnp.int32(g.n)
    if init is None:
        init = _once_device(plan, *_zeros(plan), arrays, n)
    u, v, tasks, chunk, block, interpret = _pallas_subset_schedule(
        plan, g, u, v)
    stream_u, stream_v = _pad_dyad_list(plan, u, v, pad)

    def place(dev):
        ctx = (arrays, n, stream_u, stream_v)
        return ctx if dev is None else jax.device_put(ctx, dev)

    def step(ctx, hi, lo, t):
        a, nn, su, sv = ctx
        return plan._fn(a, nn, su, sv, jnp.int32(t.start), jnp.int32(t.end),
                        hi, lo, K=int(t.key), chunk=chunk, block=block,
                        interpret=interpret)

    return plan.executor.run(tasks, place=place, step=step, init=init)


_SUBSET_RUNNERS = {"xla": _subset_xla, "distributed": _subset_distributed,
                   "pallas": _subset_pallas}


def delta_correction(plan, g_old: CSRGraph, g_new: CSRGraph,
                     delta: GraphDelta, *,
                     affected_old=None, affected_new=None) -> np.ndarray:
    """Exact per-bin correction ``raw(g_new) - raw(g_old)`` for a plan's
    fused accumulator, via two affected-subset passes (see the module
    docstring).  Costs exactly ONE counted device→host sync.  Both graphs
    must pass the plan's admission check and the plan must be on the
    device-resident path (``Plan.apply_delta`` enforces both and falls
    back to a full recompute otherwise).

    ``affected_old`` / ``affected_new`` accept precomputed
    :func:`~repro.core.delta.affected_dyads` pairs so the caller's
    footprint measurement isn't recomputed."""
    ou, ov = (affected_dyads(g_old, delta) if affected_old is None
              else affected_old)
    nu, nv = (affected_dyads(g_new, delta) if affected_new is None
              else affected_new)
    if plan.partitions > 1:
        # partitioned plans correct through the sharded subset pass: the
        # affected dyads group by owner shard and ONLY the owning shards'
        # local CSRs are rebuilt and dispatched — a delta touches the
        # shards holding its endpoints' ranges, not the whole graph.
        from .partition import subset_partitioned as runner
    else:
        runner = _SUBSET_RUNNERS[plan.backend]
    hi_o, lo_o = runner(plan, g_old, ou, ov)
    hi_n, lo_n = runner(plan, g_new, nu, nv)
    hi, lo = _acc_diff(hi_n, lo_n, hi_o, lo_o)
    return _acc_fetch(plan, hi, lo)


def run_delta(plan, g: CSRGraph, delta: GraphDelta,
              raw: "np.ndarray | None") -> DeltaResult:
    """The :meth:`repro.engine.Plan.apply_delta` implementation.

    Chooses between the affected-subset correction and a full recompute
    (``raw`` missing, footprint above ``config.delta_threshold``, the
    synchronous baseline path, or any op that opts out of the locality
    contract via ``delta_local=False``), applies it, and bumps the plan's
    ``delta_runs`` / ``delta_fulls`` counters.

    Deltas stay in ORIGINAL vertex ids under ``config.reorder``: the
    translation happens here, at the boundary.  The plan's memoized
    permutation relabels the delta (:meth:`GraphDelta.permuted`) and both
    subset passes run in relabeled space — ``apply_delta_csr`` commutes
    with relabeling because ``from_edges`` is canonical over arc sets, so
    the relabeled new graph IS the relabeling of the new graph (seeded
    into the reorder memo: a mutation stream reuses one permutation and
    every step stays warm).  The correction maps back through the inverse
    permutation before folding — exact, because ``unpermute`` is linear."""
    g_new = apply_delta_csr(g, delta)
    plan._check(g_new)
    fplan = resolve_faults(plan.config.fault_plan)
    if fplan is not None:
        # injected mid-mutate failure: the new graph exists but no counts
        # have been committed — stateful callers (the serve layer's
        # subscribed sessions) must roll back to their pre-mutation
        # (graph, raw) snapshot.  Keyed on a monotone per-plan attempt
        # counter (NOT the completed-run counters, which a failed attempt
        # never advances), so which application fails is deterministic
        # and a retry of a failed ordinal proceeds.
        ordinal = plan.stats.get("delta_attempts", 0)
        plan.stats["delta_attempts"] = ordinal + 1
        if fplan.mutate_fails(ordinal):
            raise InjectedFault(
                f"injected mid-mutate failure (delta application "
                f"#{ordinal})")
    if delta.is_empty:
        # nothing can change: zero-cost, no device work, no sync.  (The
        # raw bins are still required — an empty delta is not a run.)
        if raw is None:
            raw = plan._execute_raw(g_new)
            plan.stats["delta_fulls"] += 1
            return DeltaResult(g_new, raw, plan.layout.finalize(raw, g_new),
                               "full", 0.0)
        plan.stats["delta_runs"] += 1
        return DeltaResult(g_new, raw, plan.layout.finalize(raw, g_new),
                           "delta", 0.0)
    # reorder boundary: translate the mutation into the plan's execution
    # (relabeled) vertex space and seed the mutated graph's memo entry.
    g_x, perm = plan._reordered(g)
    if perm is not None:
        delta_x = delta.permuted(perm)
        g_new_x = apply_delta_csr(g_x, delta_x)
        plan._seed_reorder(g_new, g_new_x, perm)
    else:
        delta_x, g_new_x = delta, g_new
    affected_old = affected_dyads(g_x, delta_x)
    affected_new = affected_dyads(g_new_x, delta_x)
    frac = affected_fraction(g_x, g_new_x, len(affected_old[0]),
                             len(affected_new[0]))
    use_delta = (raw is not None and plan.device_path
                 and frac <= plan.config.delta_threshold
                 and all(getattr(op, "delta_local", True)
                         for op in plan.ops))
    if use_delta:
        corr = delta_correction(plan, g_x, g_new_x, delta_x,
                                affected_old=affected_old,
                                affected_new=affected_new)
        if perm is not None:
            corr = plan.layout.unpermute(corr, perm, g_new)
        raw_new = np.asarray(raw, dtype=np.int64) + corr
        plan.stats["delta_runs"] += 1
        mode = "delta"
    else:
        raw_new = plan._execute_raw(g_new)
        plan.stats["delta_fulls"] += 1
        mode = "full"
    return DeltaResult(g_new, raw_new, plan.layout.finalize(raw_new, g_new),
                       mode, frac)
