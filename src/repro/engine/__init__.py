"""One front door for the Triad Census: config -> plan -> result.

    from repro.engine import CensusConfig, compile_census

    plan = compile_census(graph, CensusConfig(backend="auto"))
    result = plan.run(graph)          # CensusResult, int64 counts

Backends (the paper's architecture comparison, one algorithm definition):

    "xla"          — vectorized binary-search scan (single device)
    "pallas"       — degree-bucketed VMEM tile kernel (TPU / interpret)
    "distributed"  — shard_map SPMD over a device mesh
    "auto"         — resolved from the visible hardware

Plans are cached in a bounded LRU keyed on bucketized graph metadata +
config (see :mod:`repro.engine.plan`), and execution streams the dyad
list in bounded-memory chunks through a device-resident pipeline:
on-device dyad enumeration, async double-buffered chunk dispatch, and an
on-device cross-chunk accumulator with one device→host transfer per run
(see :mod:`repro.engine.backends`).  ``CensusPlan.run_batch`` executes B
same-bucket graphs as one vmapped batch (``plan.run`` is the B = 1
case); :class:`repro.serve.CensusService` builds fleet serving on top.
The legacy entry points ``triad_census``, ``triad_census_kernel`` and
``distributed_triad_census`` are deprecated shims over this module.

Architecture walk-through: ``docs/ARCHITECTURE.md``; paper-concept index:
``docs/PAPER_MAPPING.md``.
"""
from ..core.census import CensusResult
from .config import BACKENDS, CensusConfig
from .plan import (CensusPlan, GraphMeta, clear_plan_cache, compile_census,
                   plan_cache_stats, set_plan_cache_capacity)

__all__ = [
    "BACKENDS", "CensusConfig", "CensusPlan", "CensusResult", "GraphMeta",
    "clear_plan_cache", "compile_census", "plan_cache_stats",
    "set_plan_cache_capacity",
]
