"""One front door for graph analytics: config -> plan -> results.

    from repro.engine import EngineConfig, compile

    plan = compile(graph, ["triad_census", "dyad_census", "degree_stats"],
                   EngineConfig(backend="auto"))
    results = plan.run(graph)        # {op_name: result}, one fused pass

Analytics are pluggable :class:`~repro.engine.ops.GraphOp` instances
(``triad_census``, ``dyad_census``, ``degree_stats``,
``triadic_profile`` ship built in; :func:`register_op` adds more) and any
number of them execute in **one fused pass** over the streaming dyad
pipeline: one traversal, one on-device hi/lo accumulator with a slice
per op, one device→host transfer — the memory-bound part of irregular
graph analytics (the traversal) is paid once for the whole op set.

Backends (the paper's architecture comparison, one algorithm definition):

    "xla"          — vectorized binary-search scan (single device)
    "pallas"       — degree-bucketed VMEM tile kernel (TPU / interpret)
    "distributed"  — shard_map SPMD over a device mesh
    "auto"         — resolved from the visible hardware

Plans are cached in a bounded LRU keyed on bucketized graph metadata +
op names + config (see :mod:`repro.engine.plan`), and execution streams
the dyad list in bounded-memory chunks through a device-resident
pipeline: on-device dyad enumeration, async double-buffered chunk
dispatch, and an on-device cross-chunk accumulator with one device→host
transfer per run (see :mod:`repro.engine.backends`).  Chunk dispatch
belongs to the :class:`Executor` layer
(:mod:`repro.engine.executor`): ``EngineConfig(schedule="dynamic",
n_executor_devices=...)`` carves the stream into cost-model chunks
(heavy-degree dyads get smaller chunks) and work-queues them over a
device pool — the analogue of the paper's OpenMP dynamic scheduling —
with results bit-identical to the static single-device default.  ``Plan.run_batch``
executes B same-bucket graphs as one vmapped batch (``plan.run`` is the
B = 1 case); :class:`repro.serve.CensusService` builds mixed-analytic
fleet serving on top.

The census-era API is intact: ``CensusConfig`` is the same class as
``EngineConfig``, and ``compile_census`` / :class:`CensusPlan` are thin
views over ``compile(graph, ("triad_census",), config)`` — the SAME
cache entries, bit-identical results.  The legacy entry points
``triad_census``, ``triad_census_kernel`` and
``distributed_triad_census`` are deprecated shims over this module.

Execution is fault tolerant (:mod:`repro.engine.faults`,
:mod:`repro.engine.executor`): a seeded deterministic
:class:`FaultPlan` — threaded in via ``EngineConfig(fault_plan=...)`` or
the ``REPRO_FAULT_PLAN`` environment hook — injects chunk-kernel
failures, simulated device loss, compile/runtime failures and slow
chunks with no wall clocks or runtime randomness, so every failing run
replays exactly.  Chunk kernels are functional, so bounded retry
(``max_attempts``), re-queue onto surviving pool devices, and
repeated-failure device quarantine recover **bit-identically** to the
fault-free run in one device→host sync; a graceful-degradation ladder
(pallas→xla on compile/runtime failure, dynamic→static on pool
exhaustion) is recorded in ``Plan.degradation``.

Partitioned graphs & out-of-core (:mod:`repro.core.partition`,
:mod:`repro.engine.partition`): ``EngineConfig(partitions=P)`` splits
the CSR itself into P contiguous vertex-range shards balanced by owned
canonical dyads, each carrying a local CSR plus a **halo** of the remote
neighbor rows its dyads read (exactly the ``delta_local`` locality
contract), and runs the census one shard context at a time through the
plan's own chunk machinery — per-device memory is bounded by the
largest shard, results stay bit-identical to the unpartitioned path on
every backend/schedule/op, shard accumulators merge exactly on the
primary device, and the run still costs ONE device→host sync.
``spill=True`` (or a scratch path) stages shard dyad lists through
memory-mapped files, pairing with
:func:`repro.core.graph.from_edges_mmap` so graphs and dyad streams
larger than device or host memory complete.  A delta on a partitioned
plan rebuilds only the shards owning its endpoints' ranges.

Locality-aware reordering (:mod:`repro.core.reorder`):
``EngineConfig(reorder="degree"|"bfs"|"rcm")`` relabels vertices
host-side once per (plan, graph) — memoized alongside the plan cache —
runs every chunk on the relabeled graph, and maps raw bins back through
the inverse permutation, so results stay bit-identical to
``reorder="none"`` on every backend, schedule, and delta path while the
CSR gathers of the memory-bound traversal turn near-sequential.

Architecture walk-through: ``docs/ARCHITECTURE.md``; paper-concept index:
``docs/PAPER_MAPPING.md``.
"""
from ..core.census import CensusResult
from ..core.delta import GraphDelta, affected_dyads, apply_delta_csr
from .config import BACKENDS, REORDERS, SCHEDULES, CensusConfig, EngineConfig
from .delta import DeltaResult, delta_correction
from .executor import (ChunkRetryError, ChunkTask, Executor,
                       PoolExhaustedError, WorkerFailures)
from .faults import (DeviceLostError, FaultPlan, InjectedFault,
                     fault_plan_from_env, is_poisoned, poison,
                     resolve_faults, unpoison)
from .ops import (DegreeStats, DyadCensus, GraphOp, TriadicProfile, get_op,
                  list_ops, register_op)
from .plan import (CensusPlan, GraphMeta, Plan, PlanShapeError,
                   clear_plan_cache, compile, compile_census,
                   plan_cache_stats, set_plan_cache_capacity)

__all__ = [
    "BACKENDS", "CensusConfig", "CensusPlan", "CensusResult",
    "ChunkRetryError", "ChunkTask", "DegreeStats", "DeltaResult",
    "DeviceLostError", "DyadCensus", "EngineConfig", "Executor",
    "FaultPlan", "GraphDelta", "GraphMeta", "GraphOp", "InjectedFault",
    "Plan", "PlanShapeError", "PoolExhaustedError", "REORDERS", "SCHEDULES",
    "TriadicProfile", "WorkerFailures", "affected_dyads",
    "apply_delta_csr", "clear_plan_cache", "compile", "compile_census",
    "delta_correction", "fault_plan_from_env", "get_op", "is_poisoned",
    "list_ops", "plan_cache_stats", "poison", "register_op",
    "resolve_faults", "set_plan_cache_capacity", "unpoison",
]
