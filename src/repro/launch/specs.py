"""Dry-run cell construction: (arch x shape x mesh) -> lowerable step + specs.

``build_cell`` assembles, for any assigned architecture and input shape:
  * the step function (train_step / prefill_step / serve_step),
  * abstract ``ShapeDtypeStruct`` arguments (no allocation — the pattern the
    assignment mandates),
  * per-argument ``NamedSharding``s derived from the logical rule table.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..config import SHAPES, ModelConfig, RunConfig, ShapeConfig, get_config
from ..models import transformer as tfm
from ..models.params import abstract_params, param_specs
from ..serve.decode import make_prefill_step, make_serve_step
from ..sharding.rules import batch_axes, make_rules
from ..train.optimizer import OptState
from ..train.train_step import make_train_step


class SkipCell(Exception):
    """Raised when a (arch, shape) cell is inapplicable per DESIGN.md."""


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeConfig
    cfg: ModelConfig
    run: RunConfig
    step_fn: Callable
    args: tuple
    in_shardings: tuple
    meta: dict


def _model_axis(mesh) -> int:
    return mesh.shape["model"]


def _batch_shards(mesh) -> int:
    return math.prod(mesh.shape[a] for a in batch_axes(mesh))


def default_run(arch: str, shape: ShapeConfig) -> RunConfig:
    """Baseline (pre-hillclimb) run settings per cell."""
    big = arch in ("deepseek-coder-33b", "deepseek-v2-236b", "pixtral-12b")
    micro = None
    if shape.kind == "train":
        micro = 8 if big else 4
    return RunConfig(
        attention_impl="chunked_causal",
        attention_chunk=1024,
        remat="full" if shape.kind == "train" else "none",
        microbatch=micro,
        act_shard_model=big and shape.kind == "train",
    )


def applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention arch: long_500k requires "
                       "sub-quadratic attention (DESIGN.md shape-skip note)")
    return True, ""


def make_cell_rules(cfg: ModelConfig, shape: ShapeConfig, mesh, run: RunConfig):
    ma = _model_axis(mesh)
    bs = _batch_shards(mesh)
    return make_rules(
        mesh,
        fsdp_axis=run.fsdp_axis,
        expert_sharding=("expert" if cfg.moe and cfg.moe.n_experts % ma == 0
                         else "tensor"),
        batch_shardable=shape.global_batch % bs == 0,
        seq_shard_kv=(shape.kind == "decode" and shape.global_batch % bs != 0
                      and run.seq_shard_decode),
        vocab_shardable=cfg.vocab_size % ma == 0,
        act_shard_model=run.act_shard_model,
    )


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def _param_structs(cfg, rules, mesh, dtype):
    defs = tfm.model_defs(cfg)
    structs = abstract_params(defs, dtype)
    shard = {k: _ns(mesh, s) for k, s in param_specs(defs, rules).items()}
    return structs, shard


def build_cell(arch: str, shape_name: str, mesh, run: Optional[RunConfig] = None,
               *, smoke: bool = False) -> Cell:
    cfg = get_config(arch, smoke=smoke)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    if not ok:
        raise SkipCell(why)
    run = run or default_run(arch, shape)
    rules = make_cell_rules(cfg, shape, mesh, run)
    bspec = rules.spec(("batch",))
    B, T = shape.global_batch, shape.seq_len
    meta = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
        "global_batch": B, "seq_len": T,
        "mesh": dict(mesh.shape),
        "microbatch": run.microbatch, "act_shard_model": run.act_shard_model,
        "attention_impl": run.attention_impl,
    }

    if shape.kind == "train":
        pdt = jnp.dtype(run.param_dtype)
        structs, shard = _param_structs(cfg, rules, mesh, pdt)
        opt = OptState(step=jax.ShapeDtypeStruct((), jnp.int32),
                       m=structs, v=structs)
        opt_sh = OptState(step=_ns(mesh, P()), m=shard, v=shard)
        n_text = T - cfg.n_prefix_embeds
        batch = {"tokens": jax.ShapeDtypeStruct((B, n_text + 1), jnp.int32),
                 "positions": jax.ShapeDtypeStruct((B, n_text), jnp.int32)}
        batch_sh = {"tokens": _ns(mesh, P(*(bspec + (None,)))),
                    "positions": _ns(mesh, P(*(bspec + (None,))))}
        if cfg.n_prefix_embeds:
            batch["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_prefix_embeds, cfg.d_model), jnp.bfloat16)
            batch_sh["prefix_embeds"] = _ns(mesh, P(*(bspec + (None, None))))
        step = make_train_step(cfg, run, mesh, rules,
                               microbatch=run.microbatch)
        return Cell(arch, shape, cfg, run, step,
                    (structs, opt, batch), (shard, opt_sh, batch_sh), meta)

    cdt = jnp.dtype(run.compute_dtype)
    structs, shard = _param_structs(cfg, rules, mesh, cdt)

    if shape.kind == "prefill":
        n_text = T - cfg.n_prefix_embeds
        args = [structs, jax.ShapeDtypeStruct((B, n_text), jnp.int32),
                jax.ShapeDtypeStruct((B, n_text), jnp.int32)]
        shs = [shard, _ns(mesh, P(*(bspec + (None,)))),
               _ns(mesh, P(*(bspec + (None,))))]
        if cfg.n_prefix_embeds:
            args.append(jax.ShapeDtypeStruct(
                (B, cfg.n_prefix_embeds, cfg.d_model), jnp.bfloat16))
            shs.append(_ns(mesh, P(*(bspec + (None, None)))))
        step = make_prefill_step(cfg, run, mesh, rules)
        return Cell(arch, shape, cfg, run, step, tuple(args), tuple(shs), meta)

    # decode
    cache = jax.eval_shape(
        lambda: tfm.init_cache(cfg, B, T, dtype=cdt))
    logical = tfm.cache_logical(
        cfg,
        batch_shardable=shape.global_batch % _batch_shards(mesh) == 0,
        seq_shard=(shape.global_batch % _batch_shards(mesh) != 0
                   and run.seq_shard_decode),
    )
    cache_sh = jax.tree.map(lambda lg: _ns(mesh, rules.spec(lg)), logical,
                            is_leaf=lambda x: isinstance(x, tuple) and all(
                                isinstance(i, (str, type(None))) for i in x))
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_sh = _ns(mesh, P(*(bspec + (None,))))
    cpos = jax.ShapeDtypeStruct((), jnp.int32)
    serve = make_serve_step(cfg, run, mesh, rules)
    return Cell(arch, shape, cfg, run, serve,
                (structs, cache, tokens, cpos),
                (shard, cache_sh, tok_sh, _ns(mesh, P())), meta)
