"""Roofline analysis from compiled HLO (the CPU-container profile source).

Terms per (arch, mesh), TPU v5e constants:

    compute    = HLO_FLOPs_per_chip / 197e12            [bf16 peak/chip]
    memory     = HLO_bytes_per_chip / 819e9             [HBM bw/chip]
    collective = collective_bytes_per_chip / 50e9       [per-link ICI bw]

``compiled.cost_analysis()`` on an XLA:CPU artifact counts ``while`` bodies
once (a 36-layer scan under-counts 36x), so we derive all three terms from
our own walk of the *post-partitioning optimized* HLO
(``compiled.as_text()``):

  * **flops**: every ``dot`` contributes ``2 * numel(result) * K`` (K from
    the lhs operand's contracting dims, looked up at its def site);
  * **memory**: HBM traffic modeled at fusion boundaries — every
    non-bookkeeping op at computation scope reads its operands and writes
    its result (ops *inside* ``fused_computation``s stay in registers/VMEM
    and are excluded, which is exactly the fusion contract);
  * **collectives**: result bytes of all-gather / all-reduce(2x, ring) /
    reduce-scatter / all-to-all / collective-permute;
  * every term is multiplied through ``while`` trip counts, read exactly
    from XLA's ``backend_config={"known_trip_count":{"n":...}}``.

Raw ``cost_analysis`` numbers are recorded alongside for comparison.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

# TPU v5e hardware constants (assignment-provided)
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "token": 0, "s4": 1, "u4": 1,
}

_COLLECTIVES = {
    "all-gather": 1.0,
    "all-reduce": 2.0,  # ring = reduce-scatter + all-gather
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

# ops whose standalone appearance does NOT move HBM bytes
_BOOKKEEPING = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while",
    "conditional", "call", "custom-call", "rng-bit-generator", "domain",
    "opt-barrier",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+?)(?:\.\d+)?\((.*)$")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED_RE = re.compile(
    r"(?:body|condition|to_apply|calls)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_numel_bytes(shape_str: str) -> tuple[int, int]:
    numel_total, total = 0, 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        numel_total += n
        total += n * _DTYPE_BYTES[dtype]
    return numel_total, total


def _shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class _Op:
    name: str
    shape: str
    opcode: str
    rest: str  # operand list + attributes (raw tail of the line)


@dataclasses.dataclass
class _Comp:
    name: str
    ops: list
    shapes: dict  # op name -> shape str


def parse_hlo(hlo: str):
    comps: dict[str, _Comp] = {}
    fusion_bodies: set[str] = set()
    scalar_bodies: set[str] = set()
    entry = None
    cur: Optional[_Comp] = None
    for line in hlo.splitlines():
        if line.rstrip().endswith("{") and not line.startswith((" ", "\t")):
            hdr = _COMP_HDR_RE.match(line)
            if hdr and " = " not in line.split("(")[0]:
                cur = _Comp(hdr.group(2), [], {})
                comps[cur.name] = cur
                if hdr.group(1):
                    entry = cur.name
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, shape, opcode, rest = m.groups()
        cur.ops.append(_Op(name, shape, opcode, rest))
        cur.shapes[name] = shape
        # classify called computations so the walker skips fusion internals
        if opcode == "fusion":
            cm = re.search(r"calls=%?([\w.\-]+)", rest)
            if cm:
                fusion_bodies.add(cm.group(1))
        if opcode in ("reduce", "sort", "map", "scatter", "reduce-window",
                      "select-and-scatter", "all-reduce", "reduce-scatter"):
            for c in _CALLED_RE.findall(rest):
                scalar_bodies.add(c)
    return comps, entry, fusion_bodies, scalar_bodies


def _dot_flops(op: _Op, shapes: dict) -> float:
    out_numel, _ = _shape_numel_bytes(op.shape)
    lhs_m = _OPERAND_RE.search(op.rest)
    k = 1
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    if lhs_m and cm and lhs_m.group(1) in shapes:
        dims = _shape_dims(shapes[lhs_m.group(1)])
        for ci in cm.group(1).split(","):
            if ci and int(ci) < len(dims):
                k *= dims[int(ci)]
    return 2.0 * out_numel * k


def analyze_hlo(hlo: str) -> dict:
    comps, entry, fusion_bodies, scalar_bodies = parse_hlo(hlo)
    skip = fusion_bodies | scalar_bodies
    coll_breakdown: dict[str, float] = {}

    def eval_comp(name: str, mult: float, acc: dict, seen: tuple):
        comp = comps.get(name)
        if comp is None or name in seen:
            return
        for op in comp.ops:
            base = re.match(r"([a-z\-]+)", op.opcode)
            base = base.group(1) if base else op.opcode
            if base in _COLLECTIVES:
                _, b = _shape_numel_bytes(op.shape)
                b *= _COLLECTIVES[base]
                acc["coll"] += b * mult
                coll_breakdown[base] = coll_breakdown.get(base, 0) + b * mult
            if base == "dot":
                acc["flops"] += _dot_flops(op, comp.shapes) * mult
            if base == "while":
                tm = _TRIP_RE.search(op.rest)
                trips = int(tm.group(1)) if tm else 1
                called = _CALLED_RE.findall(op.rest)
                for c in called:
                    if "condition" in op.rest.split(c)[0][-30:]:
                        eval_comp(c, mult * trips, acc, seen + (name,))
                    else:
                        eval_comp(c, mult * trips, acc, seen + (name,))
                continue
            if base == "conditional":
                bm = _BRANCHES_RE.search(op.rest)
                if bm:
                    for c in _OPERAND_RE.findall(bm.group(1)):
                        eval_comp(c, mult, acc, seen + (name,))
                continue
            if base in ("call", "fusion", "custom-call", "async-start"):
                for c in _CALLED_RE.findall(op.rest):
                    if c not in skip and base == "call":
                        eval_comp(c, mult, acc, seen + (name,))
            # in-place slice ops: only the slice region moves, not the buffer
            if base == "dynamic-update-slice":
                ops_found = _OPERAND_RE.findall(op.rest.split("),")[0])
                if len(ops_found) >= 2 and ops_found[1] in comp.shapes:
                    _, ub = _shape_numel_bytes(comp.shapes[ops_found[1]])
                    acc["bytes"] += 2 * ub * mult
                continue
            if base == "dynamic-slice":
                _, wb = _shape_numel_bytes(op.shape)
                acc["bytes"] += 2 * wb * mult
                continue
            # HBM traffic model: fusion-boundary reads + writes
            if base not in _BOOKKEEPING and base != "fusion":
                _, wb = _shape_numel_bytes(op.shape)
                rb = 0
                operand_sec = op.rest.split("),")[0]
                for o in _OPERAND_RE.findall(operand_sec):
                    if o in comp.shapes:
                        _, ob = _shape_numel_bytes(comp.shapes[o])
                        rb += ob
                acc["bytes"] += (wb + rb) * mult
            elif base == "fusion":
                _, wb = _shape_numel_bytes(op.shape)
                rb = 0
                operand_sec = op.rest.split("),")[0]
                for o in _OPERAND_RE.findall(operand_sec):
                    if o in comp.shapes:
                        _, ob = _shape_numel_bytes(comp.shapes[o])
                        rb += ob
                acc["bytes"] += (wb + rb) * mult
                # also walk fused computation for dots (rare: output fusions)
                for c in _CALLED_RE.findall(op.rest):
                    fcomp = comps.get(c)
                    if fcomp:
                        for fop in fcomp.ops:
                            if fop.opcode.startswith("dot"):
                                acc["flops"] += _dot_flops(fop, fcomp.shapes) * mult

    acc = {"flops": 0.0, "bytes": 0.0, "coll": 0.0}
    if entry:
        eval_comp(entry, 1.0, acc, ())
    acc["breakdown"] = coll_breakdown
    return acc


def roofline_terms(flops: float, bytes_hbm: float, coll_bytes: float) -> dict:
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_hbm / HBM_BW,
        "collective_s": coll_bytes / ICI_BW,
    }
    bottleneck = max(("compute_s", "memory_s", "collective_s"),
                     key=lambda k: terms[k])
    terms["bottleneck"] = bottleneck
    terms["step_s_lower_bound"] = terms[bottleneck]
    return terms


def model_flops(meta: dict) -> float:
    """MODEL_FLOPS: 6·N·D train, 2·N·D forward/prefill, 2·N·B decode."""
    n = meta["active_params"]
    if meta["kind"] == "train":
        return 6.0 * n * meta["global_batch"] * meta["seq_len"]
    if meta["kind"] == "prefill":
        return 2.0 * n * meta["global_batch"] * meta["seq_len"]
    return 2.0 * n * meta["global_batch"]  # decode: one token per request


def analyze(compiled, meta: dict) -> dict:
    ca = {}
    try:
        ca = compiled.cost_analysis() or {}
    except Exception:
        pass
    if isinstance(ca, (list, tuple)):  # jax<=0.4.x: one dict per program
        ca = ca[0] if ca else {}
    walked = analyze_hlo(compiled.as_text())
    flops = walked["flops"]
    bytes_hbm = walked["bytes"]
    coll = walked["coll"]
    n_chips = 1
    for v in meta.get("mesh", {}).values():
        n_chips *= v
    mf = model_flops(meta)
    out = {
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_hbm,
        "collective_bytes_per_chip": coll,
        "collective_breakdown": walked["breakdown"],
        "cost_analysis_flops_raw": float(ca.get("flops", 0.0)),
        "cost_analysis_bytes_raw": float(ca.get("bytes accessed", 0.0)),
        "model_flops_total": mf,
        "model_flops_per_chip": mf / n_chips,
        "useful_flops_ratio": (mf / n_chips) / flops if flops else 0.0,
        "n_chips": n_chips,
    }
    out.update(roofline_terms(flops, bytes_hbm, coll))
    denom = out["step_s_lower_bound"]
    out["roofline_fraction"] = (
        (mf / n_chips / PEAK_FLOPS) / denom if denom > 0 else 0.0)
    return out
