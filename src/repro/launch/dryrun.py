import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as its own process (the XLA flag above is consumed at first jax
init, which is why it precedes every other import — including jax).

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
        --shape train_4k [--multi-pod] [--out experiments/dryrun]

Success criteria per cell: ``.lower()`` and ``.compile()`` succeed on the
16x16 production mesh (and the 2x16x16 multi-pod mesh), and the compiled
artifact's memory_analysis / cost_analysis are recorded for §Roofline.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from . import roofline  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402
from .specs import SkipCell, build_cell  # noqa: E402


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             run_overrides: dict | None = None, tag: str = "") -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    name = f"{arch}__{shape}__{mesh_name}{('__' + tag) if tag else ''}"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, name + ".json")
    rec: dict = {"arch": arch, "shape": shape, "mesh": mesh_name, "tag": tag}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        run = None
        if run_overrides:
            from .specs import default_run
            from ..config import SHAPES
            import dataclasses as dc
            run = dc.replace(default_run(arch, SHAPES[shape]), **run_overrides)
        cell = build_cell(arch, shape, mesh, run=run)
        with mesh:
            jitted = jax.jit(cell.step_fn, in_shardings=cell.in_shardings)
            lowered = jitted.lower(*cell.args)
            rec["lower_s"] = time.time() - t0
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = time.time() - t1
        # --- proofs the assignment asks to print --------------------------
        ma = compiled.memory_analysis()
        print(ma)  # proves it fits
        ca = compiled.cost_analysis()
        print({k: ca[k] for k in ("flops", "bytes accessed")
               if ca and k in ca})
        rec["status"] = "ok"
        rec["meta"] = cell.meta
        if ma is not None:
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "generated_code_size_in_bytes",
                         "peak_memory_in_bytes"):
                v = getattr(ma, attr, None)
                if v is not None:
                    rec.setdefault("memory", {})[attr] = int(v)
        rec["roofline"] = roofline.analyze(compiled, cell.meta)
    except SkipCell as e:
        rec["status"] = "skip"
        rec["reason"] = str(e)
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug to record
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = time.time() - t0
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    print(f"[{rec['status']:4s}] {name}  ({rec['total_s']:.1f}s)",
          file=sys.stderr)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--set", action="append", default=[],
                    help="RunConfig override, e.g. --set microbatch=16")
    args = ap.parse_args()
    overrides = {}
    for s in args.set:
        k, v = s.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v
    rec = run_cell(args.arch, args.shape, args.multi_pod, args.out,
                   run_overrides=overrides or None, tag=args.tag)
    sys.exit(0 if rec["status"] in ("ok", "skip") else 1)


if __name__ == "__main__":
    main()
