import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run + roofline for the paper's technique itself: the distributed
Triad Census on the production mesh (the §Perf cell 'most representative
of the paper').

    PYTHONPATH=src python -m repro.launch.census_dryrun \
        --dataset patents [--multi-pod] [--buckets 0|1] [--strategy ...]

Unlike the LM cells the census runs the REAL paper workload shape: the
full-size Table 4.1 graph profile (no scale-down) with static dyad shards.
Since tile width K is the padding knob, ``--K`` sweeps the compute term
directly (HLO FLOPs ∝ sum of per-bucket D_i x K_i).
"""
import argparse  # noqa: E402
import json  # noqa: E402
import math  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

import numpy as np  # noqa: E402

from .. import core  # noqa: E402
from ..core import balance, generators  # noqa: E402
from ..engine import CensusConfig, compile_census  # noqa: E402
from ..engine import backends as engine_backends  # noqa: E402
from . import roofline  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="patents")
    ap.add_argument("--scale-down", type=float, default=1.0)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--strategy", default="sorted_snake")
    ap.add_argument("--weights", default="canonical_uniform")
    ap.add_argument("--K", type=int, default=0, help="tile width override")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--synthetic-stats", action="store_true",
                    help="skip graph build; use shape-only stand-in stats")
    ap.add_argument("--out", default="experiments/census")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    n_dev = math.prod(mesh.devices.shape)

    # Build (or model) the dyad workload.  For the full Patents graph the
    # host-side numpy build is expensive on 1 CPU; --scale-down shrinks the
    # graph but we keep per-device work constant by scaling tasks/device.
    g = generators.paper_profile(args.dataset, scale_down=args.scale_down)
    u, v = core.canonical_dyads(g)
    tasks = balance.pack_tasks(g, n_dev, weight_model=args.weights,
                               strategy=args.strategy,
                               pad_multiple=args.batch)
    cfg = CensusConfig(backend="distributed", batch=args.batch,
                       k=args.K or None, strategy=args.strategy,
                       weight_model=args.weights)
    plan = compile_census(g, cfg, mesh=mesh)
    K = plan.meta.k
    chunk_l = engine_backends.chunk_l(plan)

    with mesh:
        lowered = plan.aot_lower(g)
        compiled = lowered.compile()
    ma = compiled.memory_analysis()
    print(ma)

    meta = {
        "arch": f"census-{args.dataset}", "shape": f"K{K}-{args.strategy}",
        "kind": "census", "mesh": dict(mesh.shape),
        # census 'useful work': 2 membership-probe streams per candidate;
        # model flops ~ total candidate-lane work on valid lanes
        "active_params": 1, "global_batch": 1, "seq_len": 1,
    }
    r = roofline.analyze(compiled, meta)
    # census-specific useful-work model: valid candidate lanes / padded lanes
    deg = np.asarray(g.arrays.nbr_deg)
    useful_lanes = float((deg[u] + deg[v]).sum())
    L_chunked = math.ceil(tasks.u.shape[1] / chunk_l) * chunk_l
    padded_lanes = float(tasks.u.shape[0] * L_chunked * 2 * K)
    rec = {
        "dataset": args.dataset, "mesh": dict(mesh.shape), "tag": args.tag,
        "strategy": args.strategy, "weights": args.weights, "K": K,
        "chunk_l": chunk_l, "n_dyads": int(len(u)),
        "max_deg": int(g.max_deg),
        "imbalance": tasks.imbalance,
        "lane_utilization": useful_lanes / padded_lanes,
        "status": "ok",
        "memory": {a: int(getattr(ma, a)) for a in
                   ("argument_size_in_bytes", "temp_size_in_bytes",
                    "peak_memory_in_bytes") if getattr(ma, a, None) is not None},
        "roofline": {k: vv for k, vv in r.items()},
        "total_s": time.time() - t0,
    }
    os.makedirs(args.out, exist_ok=True)
    name = (f"census_{args.dataset}_{args.strategy}_K{K}"
            f"{'_multipod' if args.multi_pod else ''}"
            f"{('_' + args.tag) if args.tag else ''}")
    with open(os.path.join(args.out, name + ".json"), "w") as f:
        json.dump(rec, f, indent=1, default=str)
    print(json.dumps({k: rec[k] for k in
                      ("imbalance", "lane_utilization")}, indent=1))
    print({k: f"{vv:.3e}" if isinstance(vv, float) else vv
           for k, vv in r.items() if k.endswith("_s") or k == "bottleneck"})
    print(f"done in {rec['total_s']:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
