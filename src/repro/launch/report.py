"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from sweep JSONs."""
from __future__ import annotations

import argparse
import json
import os

ARCH_ORDER = [
    "zamba2-1.2b", "h2o-danube-3-4b", "qwen1.5-4b", "qwen3-4b",
    "deepseek-coder-33b", "pixtral-12b", "deepseek-v2-236b",
    "granite-moe-3b-a800m", "rwkv6-3b", "musicgen-large",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(out_dir: str) -> dict:
    recs = {}
    for f in os.listdir(out_dir):
        if f.endswith(".json"):
            r = json.load(open(os.path.join(out_dir, f)))
            recs[(r.get("arch"), r.get("shape"), r.get("mesh"),
                  r.get("tag", ""))] = r
    return recs


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def fmt_b(x):
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def dryrun_table(recs, mesh="pod16x16"):
    lines = ["| arch | shape | status | compile | peak mem/dev | args/dev | "
             "HLO flops/chip | coll bytes/chip |",
             "|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, mesh, ""))
            if r is None:
                continue
            if r["status"] != "ok":
                reason = r.get("reason", r.get("error", ""))[:60]
                lines.append(f"| {a} | {s} | {r['status']} ({reason}) | | | | | |")
                continue
            mem = r.get("memory", {})
            rf = r["roofline"]
            lines.append(
                f"| {a} | {s} | ok | {r.get('compile_s', 0):.0f}s "
                f"| {fmt_b(mem.get('peak_memory_in_bytes', 0))} "
                f"| {fmt_b(mem.get('argument_size_in_bytes', 0))} "
                f"| {rf['hlo_flops_per_chip']:.2e} "
                f"| {fmt_b(rf['collective_bytes_per_chip'])} |")
    return "\n".join(lines)


def roofline_table(recs, mesh="pod16x16"):
    lines = ["| arch | shape | compute | memory | collective | bottleneck | "
             "MODEL/HLO flops | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, mesh, ""))
            if r is None or r["status"] != "ok":
                continue
            rf = r["roofline"]
            lines.append(
                f"| {a} | {s} | {fmt_s(rf['compute_s'])} "
                f"| {fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} "
                f"| {rf['bottleneck'].replace('_s','')} "
                f"| {rf['useful_flops_ratio']:.2f} "
                f"| {100*rf['roofline_fraction']:.1f}% |")
    return "\n".join(lines)


def multipod_table(recs):
    lines = ["| arch | shape | 16x16 | 2x16x16 |", "|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r1 = recs.get((a, s, "pod16x16", ""))
            r2 = recs.get((a, s, "pod2x16x16", ""))
            if r1 is None and r2 is None:
                continue
            st = lambda r: (r or {}).get("status", "-")
            lines.append(f"| {a} | {s} | {st(r1)} | {st(r2)} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--section", default="all",
                    choices=["all", "dryrun", "roofline", "multipod"])
    args = ap.parse_args()
    recs = load(args.dir)
    if args.section in ("all", "dryrun"):
        print("### Dry-run (single-pod 16x16)\n")
        print(dryrun_table(recs))
        print()
    if args.section in ("all", "multipod"):
        print("### Multi-pod pass/fail\n")
        print(multipod_table(recs))
        print()
    if args.section in ("all", "roofline"):
        print("### Roofline (single-pod, per chip)\n")
        print(roofline_table(recs))


if __name__ == "__main__":
    main()
