"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — required by the dry-run ordering constraints.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for(n_devices: int, model_parallel: int = 16):
    """Elastic variant: whatever devices survive, TP degree preserved."""
    data = max(1, n_devices // model_parallel)
    return jax.make_mesh((data, model_parallel), ("data", "model"))
