"""Drive the full dry-run sweep: every (arch x shape x mesh) as a subprocess.

Each cell runs in its own process because the 512-device XLA flag must be
set before jax initializes (see dryrun.py).  Results land as JSON in
``--out``; already-completed cells are skipped unless --force.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCHS = [
    "zamba2-1.2b", "h2o-danube-3-4b", "qwen1.5-4b", "qwen3-4b",
    "deepseek-coder-33b", "pixtral-12b", "deepseek-v2-236b",
    "granite-moe-3b-a800m", "rwkv6-3b", "musicgen-large",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--archs", default=",".join(ARCHS))
    ap.add_argument("--shapes", default=",".join(SHAPES))
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()

    cells = [(a, s, m) for a in args.archs.split(",")
             for s in args.shapes.split(",")
             for m in args.meshes.split(",")]
    t0 = time.time()
    n_ok = n_fail = n_skip = 0
    for i, (arch, shape, mesh) in enumerate(cells):
        mesh_name = "pod2x16x16" if mesh == "multi" else "pod16x16"
        path = os.path.join(args.out, f"{arch}__{shape}__{mesh_name}.json")
        if os.path.exists(path) and not args.force:
            st = json.load(open(path)).get("status")
            if st in ("ok", "skip"):
                print(f"[cached {st}] {arch} {shape} {mesh_name}", flush=True)
                n_ok += st == "ok"
                n_skip += st == "skip"
                continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--out", args.out]
        if mesh == "multi":
            cmd.append("--multi-pod")
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout,
                               env={**os.environ, "PYTHONPATH": "src"})
            rec = json.load(open(path)) if os.path.exists(path) else {}
            st = rec.get("status", "fail")
        except subprocess.TimeoutExpired:
            st = "timeout"
            with open(path, "w") as f:
                json.dump({"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "timeout"}, f)
        n_ok += st == "ok"
        n_fail += st in ("fail", "timeout")
        n_skip += st == "skip"
        print(f"[{st:7s}] ({i+1}/{len(cells)}) {arch} {shape} {mesh_name} "
              f"t={time.time()-t0:.0f}s", flush=True)
    print(f"done: ok={n_ok} skip={n_skip} fail={n_fail} "
          f"in {time.time()-t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
