"""Production training launcher: sharded params, checkpointing, elasticity.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b \
        --steps 100 [--smoke] [--mesh data,model]

On the CPU container ``--smoke`` (reduced config, 1-device mesh) is the
runnable path; on a TPU fleet the same code drives the production mesh
(devices are discovered via jax.devices(), TP degree preserved on elastic
restarts via train.elastic.plan_elastic_mesh).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..config import RunConfig, get_config
from ..data import SyntheticTokens
from ..models import transformer as tfm
from ..models.params import param_specs
from ..sharding.rules import batch_axes, make_rules
from ..train import CheckpointManager, adamw_init, make_train_step
from ..train.elastic import StepWatchdog, plan_elastic_mesh
from ..train.optimizer import OptState


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--model-parallel", type=int, default=0,
                    help="TP degree (0 = all devices on one data axis)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    run = RunConfig(attention_impl="chunked_causal",
                    attention_chunk=min(1024, args.seq))
    n_dev = len(jax.devices())
    mp = args.model_parallel or 1
    shape = plan_elastic_mesh(n_dev, mp) if mp > 1 else (n_dev, 1)
    mesh = jax.make_mesh(shape, ("data", "model"))
    rules = make_rules(mesh, vocab_shardable=cfg.vocab_size % shape[1] == 0)
    print(f"mesh={dict(mesh.shape)} params={cfg.param_count()/1e6:.1f}M")

    defs_specs = {k: NamedSharding(mesh, s) for k, s in
                  param_specs(tfm.model_defs(cfg), rules).items()}
    with mesh:
        params = jax.jit(
            lambda k: tfm.init_model(cfg, k),
            out_shardings=defs_specs)(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        step_fn = jax.jit(make_train_step(
            cfg, run, mesh, rules,
            microbatch=args.microbatch or None,
            total_steps=args.steps, warmup=max(2, args.steps // 10)))

        mgr = CheckpointManager(args.ckpt_dir, keep=2)
        start = mgr.latest_step()
        if start is not None:
            trees, _ = mgr.restore(start, shardings={
                "params": defs_specs, "m": defs_specs, "v": defs_specs})
            params = trees["params"]
            opt = OptState(step=jnp.int32(start), m=trees["m"], v=trees["v"])
            print(f"elastic resume from step {start} onto {dict(mesh.shape)}")
        else:
            start = 0

        ds = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=args.seq,
                             global_batch=args.batch)
        bspec = NamedSharding(mesh, P(batch_axes(mesh), None))
        wd = StepWatchdog()
        for i in range(start, args.steps):
            wd.start()
            batch = {"tokens": jax.device_put(ds.batch_at(i), bspec)}
            params, opt, mets = step_fn(params, opt, batch)
            straggler = wd.stop(i)
            if i % 5 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss={float(mets['loss']):.4f}"
                      + ("  [straggler]" if straggler else ""), flush=True)
            if (i + 1) % args.ckpt_every == 0:
                mgr.save(i + 1, {"params": params, "m": opt.m, "v": opt.v},
                         meta={"step": i + 1})
        mgr.wait()
    print("done")


if __name__ == "__main__":
    main()
