from .rules import (Rules, batch_axes, logical_to_spec, make_rules,
                    named_sharding)

__all__ = ["Rules", "batch_axes", "logical_to_spec", "make_rules",
           "named_sharding"]
