"""Deprecated alias for :mod:`repro.sharding.rules`.

The ``partition`` name now belongs to the graph-partitioning subsystem
(:mod:`repro.core.partition` — vertex-range cuts, halos, local CSRs —
and :mod:`repro.engine.partition` — the sharded execution path behind
``EngineConfig(partitions=...)``).  The MaxText-style logical-axis
sharding rules this module used to hold live unchanged in
:mod:`repro.sharding.rules`; importing them from here keeps working but
emits a :class:`DeprecationWarning`.
"""
from __future__ import annotations

import warnings

from .rules import (Rules, batch_axes, constrain, logical_to_spec,
                    make_rules, named_sharding)

__all__ = ["Rules", "batch_axes", "constrain", "logical_to_spec",
           "make_rules", "named_sharding"]

warnings.warn(
    "repro.sharding.partition is deprecated: the logical-axis sharding "
    "rules moved to repro.sharding.rules (the 'partition' name now means "
    "graph partitioning — see repro.core.partition / "
    "repro.engine.partition)", DeprecationWarning, stacklevel=2)
