"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Mesh axes: ``('data', 'model')`` single-pod, ``('pod', 'data', 'model')``
multi-pod.  ``pod`` acts as an outer data axis.

Weight layout convention: projection weights are stored 2-D with their
output features *flattened* (``H*hd``), because head counts of the assigned
archs (20, 56, 24, 40 heads / 40 experts) are not all divisible by the
16-way model axis while the flat feature dims always are.  ``jit``
in_shardings must divide evenly; intermediate per-head tensors rely on
GSPMD's padded propagation instead.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes that jointly shard the batch (pod is an outer data axis)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


@dataclasses.dataclass(frozen=True)
class Rules:
    """logical axis name -> mesh axis (or None = replicate)."""

    table: dict

    def spec(self, logical: tuple) -> P:
        return P(*(self.table.get(ax) for ax in logical))


def make_rules(
    mesh: Mesh,
    *,
    fsdp_axis: Optional[str] = "data",
    expert_sharding: str = "expert",  # 'expert' | 'tensor'
    batch_shardable: bool = True,
    seq_shard_kv: bool = False,
    vocab_shardable: bool = True,
    act_shard_model: bool = False,
) -> Rules:
    """Build the rule table for this mesh.

    ``expert_sharding='expert'`` places experts on the model axis (true
    expert parallelism; requires n_experts % model == 0);  ``'tensor'``
    replicates the expert dim and tensor-parallelizes each expert's ffn
    (used for granite-moe's 40 experts on a 16-way axis).

    ``seq_shard_kv`` shards decode KV caches over the data axes (sequence-
    parallel flash-decode for long_500k, where batch=1 is unshardable).
    ``vocab_shardable=False`` replicates embedding params (granite's 49155
    vocab is not divisible by 16); logits still shard via constraints.
    ``act_shard_model`` additionally shards the saved residual stream over
    the model axis (Megatron-SP style activation partitioning; trades one
    all-gather per layer for 16x less activation stash — a hillclimb knob).
    """
    b_mesh = batch_axes(mesh)
    b_axes = b_mesh if batch_shardable else None
    table = {
        None: None,
        "batch": b_axes,
        "seq": None,
        "kv_seq": b_mesh if seq_shard_kv else None,
        "mla_seq": "model",  # compressed-KV decode: shard cache over seq
        "embed": fsdp_axis,  # weight in-features (FSDP/ZeRO-3 axis)
        "ff": "model",
        "heads_flat": "model",
        "kv_flat": "model",
        "vocab": "model" if vocab_shardable else None,
        "logit_vocab": "model",
        "lora": None,
        "state": None,
        "layers": None,
        "act_embed": "model" if act_shard_model else None,
        "experts": "model" if expert_sharding == "expert" else None,
        "expert_ff": None if expert_sharding == "expert" else "model",
        "expert_embed": fsdp_axis,
    }
    return Rules(table=table)


def logical_to_spec(rules: Rules, logical: tuple) -> P:
    return rules.spec(logical)


def named_sharding(mesh: Mesh, rules: Rules, logical: tuple) -> NamedSharding:
    return NamedSharding(mesh, rules.spec(logical))


def constrain(x: jax.Array, mesh: Mesh, rules: Rules, logical: tuple) -> jax.Array:
    """with_sharding_constraint via logical names (no-op off-mesh)."""
    try:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, rules.spec(logical))
        )
    except (ValueError, RuntimeError):
        return x
