"""The paper's own experiment configs (Table 4.1 datasets as census jobs).

These parameterize launch/census_dryrun.py and examples/triad_census_sna.py;
on a real cluster point ``path`` at the actual Pajek/SNAP files and the
loader in core.graph takes over from the R-MAT stand-ins.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from ..core.generators import PAPER_DATASETS


@dataclasses.dataclass(frozen=True)
class CensusJobConfig:
    dataset: str
    n_vertices: int
    n_arcs: int
    directed: bool
    path: Optional[str] = None  # real dataset file (Pajek / edge list)
    strategy: str = "sorted_snake"
    weight_model: str = "canonical_uniform"
    batch: int = 256
    buckets: tuple = (64, 256, 1024)  # degree-bucket tile widths


CENSUS_JOBS: dict[str, CensusJobConfig] = {
    name: CensusJobConfig(dataset=name, n_vertices=n, n_arcs=m, directed=d)
    for name, (n, m, d) in PAPER_DATASETS.items()
}


def get_census_job(name: str) -> CensusJobConfig:
    return CENSUS_JOBS[name]
