"""h2o-danube-3-4b [dense]: llama+mistral mix with sliding-window attention.

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000 [arXiv:2401.16818;
unverified].  Window 4096 => sub-quadratic, long_500k eligible.
"""
from ..config.base import ModelConfig
from ..config.registry import register


@register("h2o-danube-3-4b")
def full() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b", family="dense", n_layers=24, d_model=3840,
        n_heads=32, n_kv_heads=8, d_ff=10240, vocab_size=32000,
        head_dim=120, sliding_window=4096, rope_theta=500_000.0,
        notes="SWA window 4096; long_500k eligible.",
    )


@register("h2o-danube-3-4b:smoke")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b:smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
        sliding_window=16,
    )
