"""rwkv6-3b [ssm]: Finch — attention-free, data-dependent decay.

32L d_model=2560 d_ff=8960 vocab=65536 [arXiv:2404.05892; hf].
O(1) state per token => long_500k eligible.
"""
from ..config.base import ModelConfig, RWKVConfig
from ..config.registry import register


@register("rwkv6-3b")
def full() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b", family="ssm", n_layers=32, d_model=2560,
        n_heads=40, n_kv_heads=40, d_ff=8960, vocab_size=65536,
        rwkv=RWKVConfig(head_dim=64, decay_lora=64, chunk=32),
        notes="attention-free; census technique n/a to model math.",
    )


@register("rwkv6-3b:smoke")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b:smoke", family="ssm", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
        rwkv=RWKVConfig(head_dim=16, decay_lora=8, chunk=8),
    )
