"""qwen3-4b [dense]: 36L d_model=2560 32H (GQA kv=8) d_ff=9728
vocab=151936, qk-norm, head_dim 128 [hf:Qwen/Qwen3 family; hf]."""
from ..config.base import ModelConfig
from ..config.registry import register


@register("qwen3-4b")
def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b", family="dense", n_layers=36, d_model=2560,
        n_heads=32, n_kv_heads=8, d_ff=9728, vocab_size=151936,
        head_dim=128, qk_norm=True, rope_theta=1_000_000.0,
    )


@register("qwen3-4b:smoke")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b:smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
        qk_norm=True,
    )
