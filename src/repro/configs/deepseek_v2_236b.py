"""deepseek-v2-236b [moe]: MLA (kv_lora=512) + 2 shared / 160 routed top-6.

60L d_model=5120 128H d_ff_expert=1536 vocab=102400 [arXiv:2405.04434; hf].
First layer dense (d_ff=12288 as published); expert parallelism over the
16-way model axis (160 % 16 == 0).  The MoE dispatch reuses the paper's
static-balanced-shards + decoupled-merge discipline (DESIGN.md §4).
"""
from ..config.base import MLAConfig, MoEConfig, ModelConfig
from ..config.registry import register


@register("deepseek-v2-236b")
def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="moe", n_layers=60, d_model=5120,
        n_heads=128, n_kv_heads=128, d_ff=12288, vocab_size=102400,
        rope_theta=10_000.0,
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
                      nope_head_dim=128, v_head_dim=128),
        moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536,
                      n_shared_experts=2, d_ff_shared=1536,
                      capacity_factor=1.25, first_dense_layers=1),
    )


@register("deepseek-v2-236b:smoke")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b:smoke", family="moe", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
        mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48, rope_head_dim=8,
                      nope_head_dim=16, v_head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                      n_shared_experts=1, d_ff_shared=32,
                      capacity_factor=2.0, first_dense_layers=1),
    )
