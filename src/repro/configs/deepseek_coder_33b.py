"""deepseek-coder-33b [dense]: 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256, llama-arch [arXiv:2401.14196; hf]."""
from ..config.base import ModelConfig
from ..config.registry import register


@register("deepseek-coder-33b")
def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b", family="dense", n_layers=62, d_model=7168,
        n_heads=56, n_kv_heads=8, d_ff=19200, vocab_size=32256,
        head_dim=128, rope_theta=100_000.0,
        notes="56 heads % 16 != 0: head sharding via flat (H*hd) layout.",
    )


@register("deepseek-coder-33b:smoke")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b:smoke", family="dense", n_layers=2,
        d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
        head_dim=16,
    )
