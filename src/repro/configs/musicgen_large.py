"""musicgen-large [audio]: decoder-only over EnCodec tokens.

48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048 [arXiv:2306.05284;
hf].  EnCodec quantizer + 4-codebook delay pattern STUBBED to a single
token stream (tokens ARE the input; see DESIGN.md §7).
"""
from ..config.base import ModelConfig
from ..config.registry import register


@register("musicgen-large")
def full() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large", family="audio", n_layers=48, d_model=2048,
        n_heads=32, n_kv_heads=32, d_ff=8192, vocab_size=2048,
        notes="EnCodec frontend stub; full attention => long_500k skipped.",
    )


@register("musicgen-large:smoke")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large:smoke", family="audio", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=64,
    )
