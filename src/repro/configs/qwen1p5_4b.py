"""qwen1.5-4b [dense]: 40L d_model=2560 20H (GQA kv=20) d_ff=6912
vocab=151936, QKV bias [hf:Qwen/Qwen1.5-0.5B family; hf]."""
from ..config.base import ModelConfig
from ..config.registry import register


@register("qwen1.5-4b")
def full() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b", family="dense", n_layers=40, d_model=2560,
        n_heads=20, n_kv_heads=20, d_ff=6912, vocab_size=151936,
        qkv_bias=True, rope_theta=1_000_000.0,
        notes="20 heads % 16 != 0: head sharding via flat (H*hd) layout.",
    )


@register("qwen1.5-4b:smoke")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b:smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256, qkv_bias=True,
    )
