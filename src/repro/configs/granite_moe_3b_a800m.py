"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff_exp=512
vocab=49155, MoE 40e top-8 [hf:ibm-granite; hf].

Assignment line also says "32 experts top-8"; we implement 40 experts
top-8 per the shape spec (noted in DESIGN.md §7).  40 % 16 != 0 so expert
weights use tensor-parallel sharding ('tensor' mode); vocab 49155 % 16 != 0
so embedding params replicate over vocab (logits still shard).
"""
from ..config.base import MoEConfig, ModelConfig
from ..config.registry import register


@register("granite-moe-3b-a800m")
def full() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe", n_layers=32, d_model=1536,
        n_heads=24, n_kv_heads=8, d_ff=512, vocab_size=49155,
        head_dim=64, tie_embeddings=True,
        moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512,
                      capacity_factor=1.25),
    )


@register("granite-moe-3b-a800m:smoke")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m:smoke", family="moe", n_layers=2,
        d_model=64, n_heads=4, n_kv_heads=2, d_ff=32, vocab_size=255,
        head_dim=16, tie_embeddings=True,
        moe=MoEConfig(n_experts=5, top_k=2, d_ff_expert=32,
                      capacity_factor=2.0),
    )
