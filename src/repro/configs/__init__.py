"""Assigned architecture configs (one module per arch) + paper census configs."""
from ..config.registry import ARCH_MODULES, get_config, list_configs  # noqa: F401
