"""pixtral-12b [vlm]: mistral-nemo decoder backbone; ViT frontend STUBBED.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072
[hf:mistralai/Pixtral-12B-2409; unverified].  input_specs() supplies
precomputed patch embeddings (B, 1024, d) prepended to text tokens.
"""
from ..config.base import ModelConfig
from ..config.registry import register


@register("pixtral-12b")
def full() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="vlm", n_layers=40, d_model=5120,
        n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=131072,
        head_dim=128, rope_theta=1_000_000.0, n_prefix_embeds=1024,
        notes="vision frontend stub: precomputed patch embeddings input.",
    )


@register("pixtral-12b:smoke")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b:smoke", family="vlm", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
        n_prefix_embeds=8,
    )
