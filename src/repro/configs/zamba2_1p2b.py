"""zamba2-1.2b [hybrid]: Mamba2 backbone + weight-shared GQA attention blocks.

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf].  Shared attention invoked every 6th layer (6 sites;
real Zamba2 adds per-invocation LoRA — stubbed, see DESIGN.md §7).
"""
from ..config.base import ModelConfig, SSMConfig
from ..config.registry import register


@register("zamba2-1.2b")
def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048,
        n_heads=32, n_kv_heads=32, d_ff=8192, vocab_size=32000,
        ssm=SSMConfig(d_state=64, expand=2, head_dim=64, conv_width=4,
                      chunk=128, n_groups=1, attn_every=6),
        notes="Mamba2 + shared attn; long_500k eligible (hybrid).",
    )


@register("zamba2-1.2b:smoke")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b:smoke", family="hybrid", n_layers=7, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
        ssm=SSMConfig(d_state=16, expand=2, head_dim=16, conv_width=4,
                      chunk=16, n_groups=1, attn_every=3),
    )
