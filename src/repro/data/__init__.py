from .pipeline import SyntheticTokens, TokenFileDataset

__all__ = ["SyntheticTokens", "TokenFileDataset"]
