"""Data pipeline: deterministic synthetic tokens + memmapped token files.

Determinism-by-construction is the fault-tolerance story: batch ``i`` is a
pure function of ``(seed, i, shard)``, so resuming from a checkpointed step
counter reproduces the exact stream — no iterator state to persist, and an
elastic restart with a different shard count re-slices the same stream.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticTokens:
    """Markov-ish synthetic LM data (learnable structure, not uniform noise)."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    shard: int = 0
    n_shards: int = 1

    def __post_init__(self):
        if self.global_batch % self.n_shards:
            raise ValueError("global_batch must divide by n_shards")
        self.local_batch = self.global_batch // self.n_shards

    def batch_at(self, step: int) -> np.ndarray:
        """(local_batch, seq_len + 1) int32 — inputs+labels in one array."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard]))
        B, T, V = self.local_batch, self.seq_len + 1, self.vocab_size
        # order-1 structure: next token = (prev * a + noise) % V
        a = 31 if V > 31 else 3
        x = np.empty((B, T), dtype=np.int64)
        x[:, 0] = rng.integers(0, V, B)
        noise = rng.integers(0, max(V // 16, 2), (B, T))
        for t in range(1, T):
            x[:, t] = (x[:, t - 1] * a + noise[:, t]) % V
        return x.astype(np.int32)

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass
class TokenFileDataset:
    """Memmapped pre-tokenized corpus (the real-cluster path)."""

    path: str
    seq_len: int
    global_batch: int
    shard: int = 0
    n_shards: int = 1
    dtype: str = "int32"

    def __post_init__(self):
        self.tokens = np.memmap(self.path, dtype=self.dtype, mode="r")
        self.local_batch = self.global_batch // self.n_shards
        self.per_step = self.global_batch * (self.seq_len + 1)
        self.n_steps = len(self.tokens) // self.per_step

    def batch_at(self, step: int) -> np.ndarray:
        step = step % max(self.n_steps, 1)
        base = step * self.per_step + self.shard * self.local_batch * (self.seq_len + 1)
        flat = self.tokens[base: base + self.local_batch * (self.seq_len + 1)]
        return np.asarray(flat, dtype=np.int32).reshape(self.local_batch,
                                                        self.seq_len + 1)
