"""Version-portability shims for the installed JAX.

The repo targets the modern ``jax.shard_map`` API (with ``check_vma``) but
must also run on JAX 0.4.x where SPMD mapping lives in
``jax.experimental.shard_map`` (with ``check_rep``) and ``jax.lax.pvary``
does not exist.  Everything that shard-maps goes through this module so the
version split lives in exactly one place.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map", "pvary"]


if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)

else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
        # pre-0.5 spelling: replication checking is ``check_rep``.  The
        # checker predates ``pvary`` so code written for the modern API
        # (where unmapped inputs must be explicitly varied) trips false
        # positives; callers here always opt out.
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)


if hasattr(jax.lax, "pvary"):
    pvary = jax.lax.pvary
else:

    def pvary(x, axis_name):
        """No-op fallback: pre-0.5 shard_map has no varying-manual types."""
        return x
