from .optimizer import adamw_init, adamw_update, OptState
from .train_step import make_loss_fn, make_train_step
from .checkpoint import CheckpointManager

__all__ = ["CheckpointManager", "OptState", "adamw_init", "adamw_update",
           "make_loss_fn", "make_train_step"]
