"""AdamW with decoupled weight decay, global-norm clipping, cosine schedule.

Optimizer state is sharded identically to the parameters (the rules table
maps each moment to its parameter's spec), which under GSPMD is the ZeRO-3
equivalent: every device holds only its (1/data x 1/model) slice of m and v.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..config.base import RunConfig


class OptState(NamedTuple):
    step: jax.Array  # () int32
    m: dict
    v: dict


def adamw_init(params: dict) -> OptState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree.map(jnp.zeros_like, params))


def cosine_schedule(step, base_lr, warmup=100, total=10_000, min_frac=0.1):
    step = step.astype(jnp.float32)
    warm = base_lr * step / warmup
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, base_lr * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


_NO_DECAY_SUBSTRINGS = ("ln", "norm", "bias", "b_", "/b", "mu_", "A_log", "dt_bias", "/u", "/D")


def _decay_mask(path: str) -> bool:
    return not any(s in path for s in _NO_DECAY_SUBSTRINGS)


def adamw_update(params: dict, grads: dict, opt: OptState, run: RunConfig,
                 *, total_steps: int = 10_000, warmup: int = 100):
    grads, gnorm = clip_by_global_norm(grads, run.grad_clip)
    step = opt.step + 1
    lr = cosine_schedule(step, run.learning_rate, total=total_steps,
                         warmup=warmup)
    b1, b2, eps = run.adam_b1, run.adam_b2, 1e-8
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    new_params, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k].astype(jnp.float32)
        m = b1 * opt.m[k] + (1 - b1) * g
        v = b2 * opt.v[k] + (1 - b2) * jnp.square(g)
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        if _decay_mask(k):
            upd = upd + run.weight_decay * params[k].astype(jnp.float32)
        new_params[k] = (params[k].astype(jnp.float32) - lr * upd).astype(params[k].dtype)
        new_m[k] = m
        new_v[k] = v
    return new_params, OptState(step=step, m=new_m, v=new_v), {
        "grad_norm": gnorm, "lr": lr}


# --- gradient compression (int8 quantize/dequantize with stochastic rounding)

def compress_grads_int8(grads: dict, key: jax.Array) -> dict:
    """Per-tensor int8 quantization round-trip.

    On a real fleet this wraps the cross-replica all-reduce (4x less ICI
    traffic per gradient sync); here the quantize->dequantize round-trip is
    applied at the same point in the dataflow so its *numerical* effect on
    training is exactly reproduced and testable.
    """
    out = {}
    for i, k in enumerate(sorted(grads)):
        g = grads[k].astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        sub = jax.random.fold_in(key, i)
        noise = jax.random.uniform(sub, g.shape, minval=-0.5, maxval=0.5)
        q = jnp.clip(jnp.round(g / scale + noise), -127, 127).astype(jnp.int8)
        out[k] = q.astype(jnp.float32) * scale
    return out
