"""Fault-tolerant checkpointing: atomic, async, auto-resume, reshardable.

Design (DESIGN.md §5):
  * **atomic**: write to ``<dir>/tmp.<step>`` then ``os.rename`` — a crash
    mid-save can never corrupt the latest checkpoint;
  * **async**: device->host transfer happens synchronously (cheap), disk IO
    on a background thread so the train loop keeps stepping;
  * **auto-resume**: ``latest_step()`` scans for the newest *complete*
    checkpoint (marked by a MANIFEST file written last);
  * **elastic restore**: arrays are re-``device_put`` with the *current*
    mesh's NamedShardings, so a job restarted on a different topology
    (e.g. 512 -> 256 chips after losing a pod) resumes transparently;
  * data-pipeline state is one integer (the step) because the pipeline is
    deterministic-by-construction (data/pipeline.py) — no iterator blobs.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np

MANIFEST = "MANIFEST.json"


def _safe(path: str) -> str:
    return path.replace("/", "__")


def _unsafe(name: str) -> str:
    return name.replace("__", "/")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, trees: dict[str, dict], meta: dict | None = None):
        """trees: {'params': flatdict, 'opt_m': flatdict, ...} of jax arrays."""
        host = {
            tname: {k: np.asarray(v) for k, v in tree.items()}
            for tname, tree in trees.items()
        }
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, meta or {}), daemon=True)
            self._thread.start()
        else:
            self._write(step, host, meta or {})

    def _write(self, step: int, host: dict, meta: dict):
        tmp = os.path.join(self.dir, f"tmp.{step}")
        final = os.path.join(self.dir, f"step_{step:010d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        index = {}
        for tname, tree in host.items():
            sub = os.path.join(tmp, tname)
            os.makedirs(sub)
            for k, arr in tree.items():
                np.save(os.path.join(sub, _safe(k) + ".npy"), arr)
            index[tname] = sorted(tree)
        with open(os.path.join(tmp, MANIFEST), "w") as f:
            json.dump({"step": step, "index": index, "meta": meta,
                       "time": time.time()}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                if os.path.exists(os.path.join(self.dir, name, MANIFEST)):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, shardings: dict[str, dict] | None = None):
        """Load trees; optionally re-place with per-leaf NamedShardings
        (elastic restore onto whatever mesh the caller now has)."""
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, MANIFEST)) as f:
            manifest = json.load(f)
        out = {}
        for tname, keys in manifest["index"].items():
            tree = {}
            for k in keys:
                arr = np.load(os.path.join(d, tname, _safe(k) + ".npy"))
                if shardings and tname in shardings and k in shardings[tname]:
                    tree[k] = jax.device_put(arr, shardings[tname][k])
                else:
                    tree[k] = jax.numpy.asarray(arr)
            out[tname] = tree
        return out, manifest["meta"]
