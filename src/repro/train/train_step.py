"""Train-step factory: CE loss, microbatch accumulation, remat, compression.

``make_train_step`` returns a pure function suitable for ``jax.jit`` with
sharded params/opt-state; the data-parallel gradient reduction is implicit
in GSPMD (it shows up as reduce-scatter/all-reduce collectives in the
lowered HLO, which the roofline analysis in launch/roofline.py parses).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..config.base import ModelConfig, RunConfig
from ..models.transformer import make_forward
from .optimizer import OptState, adamw_update, compress_grads_int8


def make_loss_fn(cfg: ModelConfig, run: RunConfig, mesh=None, rules=None):
    fwd = make_forward(cfg, run, mesh, rules)

    def loss_fn(params, batch):
        tokens = batch["tokens"]  # (B, T+1)
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
        B, T = inputs.shape
        # positions as *runtime* data when provided: keeps XLA from
        # constant-folding causal masks into giant per-iteration buffers.
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None],
                                         (B, T))
        prefix = batch.get("prefix_embeds")
        logits, _, aux = fwd(params, inputs, positions, prefix_embeds=prefix)
        if prefix is not None:
            logits = logits[:, prefix.shape[1]:]
        logits = logits.astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        ce = -jnp.mean(ll)
        loss = ce + aux
        return loss, {"ce": ce, "aux": aux}

    return loss_fn


def make_train_step(cfg: ModelConfig, run: RunConfig, mesh=None, rules=None,
                    *, microbatch: Optional[int] = None,
                    total_steps: int = 10_000, warmup: int = 100):
    loss_fn = make_loss_fn(cfg, run, mesh, rules)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state: OptState, batch):
        if microbatch and microbatch > 1:
            n = microbatch

            def resh(x):
                return x.reshape(n, x.shape[0] // n, *x.shape[1:])

            micro = jax.tree.map(resh, batch)

            def acc_body(carry, mb):
                gacc, lacc = carry
                (loss, mets), grads = grad_fn(params, mb)
                gacc = jax.tree.map(jnp.add, gacc, grads)
                return (gacc, lacc + loss), mets

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), mets = jax.lax.scan(acc_body, (g0, jnp.float32(0)),
                                              micro)
            grads = jax.tree.map(lambda g: g / n, gsum)
            loss = lsum / n
            metrics = jax.tree.map(lambda m: m.mean(), mets)
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        if run.grad_compression == "int8":
            key = jax.random.fold_in(jax.random.PRNGKey(17), opt_state.step)
            grads = compress_grads_int8(grads, key)

        params, opt_state, opt_mets = adamw_update(params, grads, opt_state,
                                                   run, total_steps=total_steps,
                                                   warmup=warmup)
        metrics = {**metrics, **opt_mets, "loss": loss}
        return params, opt_state, metrics

    return train_step
