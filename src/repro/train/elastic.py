"""Elastic scaling + straggler mitigation utilities.

On a real fleet these hook into the cluster manager; everything here is the
device-count-agnostic logic that CAN run (and is tested) in this container:

  * ``reshard_tree``      — move any pytree onto a new mesh's shardings
                            (checkpoint-free pod-loss recovery when the
                            arrays still exist; checkpointed recovery path
                            is train/checkpoint.py).
  * ``StepWatchdog``      — per-step wall-time tracker that flags stragglers
                            (steps > k x rolling median) and exposes the
                            skip/requeue decision the launcher acts on.
  * ``plan_elastic_mesh`` — given surviving device count, pick the largest
                            (data, model) grid that preserves the model axis
                            (TP degree must not change; DP shrinks).
"""
from __future__ import annotations

import collections
import statistics
import time

import jax
from jax.sharding import Mesh, NamedSharding


def reshard_tree(tree, mesh: Mesh, specs):
    """device_put every leaf with its spec on the (new) mesh."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs)


def plan_elastic_mesh(n_devices: int, model_parallel: int = 16):
    """Largest (data, model) grid keeping TP fixed; DP absorbs the loss."""
    if n_devices < model_parallel:
        raise ValueError(
            f"need >= {model_parallel} devices to preserve TP degree")
    data = n_devices // model_parallel
    return (data, model_parallel)


class StepWatchdog:
    """Flags straggling steps; on a fleet the launcher swaps in hot spares."""

    def __init__(self, factor: float = 3.0, window: int = 32):
        self.factor = factor
        self.times = collections.deque(maxlen=window)
        self._t0 = None
        self.stragglers: list[tuple[int, float]] = []

    def start(self):
        self._t0 = time.monotonic()

    def stop(self, step: int) -> bool:
        dt = time.monotonic() - self._t0
        is_straggler = False
        if len(self.times) >= 8:
            med = statistics.median(self.times)
            if dt > self.factor * med:
                self.stragglers.append((step, dt))
                is_straggler = True
        self.times.append(dt)
        return is_straggler

    @property
    def median(self) -> float:
        return statistics.median(self.times) if self.times else 0.0
