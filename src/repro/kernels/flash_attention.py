"""Pallas TPU flash-attention kernel (causal, GQA, optional sliding window).

Grid ``(B, H, n_q, n_kv)`` with the kv dim minor (sequential on a TPU
core); per-(b,h,q-block) running max / denominator / accumulator live in
VMEM scratch across kv steps — the HBM traffic is exactly q, k, v, o (the
collapse of the XLA chunked path's fusion-boundary score traffic measured
in EXPERIMENTS.md §Perf).  Causal skipping: kv blocks strictly above the
diagonal contribute nothing and are skipped via ``pl.when``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, window, n_kv, scale):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qp = qpos_ref[0]  # (Cq,)
    kp = kpos_ref[0]  # (Ck,)

    # block-level causal/window reachability (static grid -> pl.when)
    def body():
        q = q_ref[0, 0]  # (Cq, D)
        k = k_ref[0, 0]  # (Ck, D)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (Cq, Ck)
        mask = kp[None, :] <= qp[:, None]
        if window is not None:
            mask &= kp[None, :] > qp[:, None] - window
        s = s + jnp.where(mask, 0.0, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    # skip fully-masked blocks: possible only when positions are the
    # canonical arange (the wrapper guarantees it); else always compute.
    pl.when(ki <= qi)(body)

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, q_pos, kv_pos, *, window=None,
                           block_q: int = 128, block_kv: int = 128,
                           interpret: bool = True):
    """q: (B, T, H, D); k/v: (B, S, Hkv, D); positions (B, T)/(B, S).

    Requires T % block_q == 0, S % block_kv == 0, and ascending positions
    (prefill layout) for the causal block-skip to be sound.
    """
    B, T, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    block_q = min(block_q, T)
    block_kv = min(block_kv, S)
    n_q, n_kv = T // block_q, S // block_kv
    grid = (B, H, n_q, n_kv)

    qs = q.transpose(0, 2, 1, 3)  # (B, H, T, D)
    ks = k.transpose(0, 2, 1, 3)  # (B, Hkv, S, D)
    vs = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_flash_kernel, window=window, n_kv=n_kv,
                               scale=1.0 / math.sqrt(D))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q), lambda b, h, i, j: (b, i)),
            pl.BlockSpec((1, block_kv), lambda b, h, i, j: (b, j)),
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q_pos, kv_pos, qs, ks, vs)
    return out.transpose(0, 2, 1, 3)  # (B, T, H, D)
