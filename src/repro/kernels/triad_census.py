"""Pallas TPU kernel for the Triad Census inner loop (the paper's hot spot).

TPU-native design (DESIGN.md §2): instead of the GPU kernel's per-thread
linked-CSR walks + constant-memory table lookups, each grid step processes
a **block of B dyads** whose neighborhoods arrive as dense, sentinel-padded
``(B, K)`` VMEM tiles:

  * every ``IsEdge``/``IsNeighbour`` probe is a broadcast compare against a
    VMEM-resident row tile followed by an any-reduce — 8x128-lane VPU work,
    no gather, no divergence (the four directed probes were rewritten as
    memberships in OUT(u)/IN(u)/OUT(v)/IN(v), all *block-loadable* rows);
  * the 64->16 isomorphism mapping is a one-hot (16, 64) matmul against the
    per-block 64-bin histogram (the GPU version's serialized constant-cache
    reads have no TPU analogue — the MXU does the mapping in one shot);
  * each grid step writes a private 16-bin partial census; the host-side
    wrapper sums them (the paper's decoupled per-thread-block census).

Degree-bucketing: tiles are sized K = max degree of the *bucket*, so the
kernel is launched per degree bucket (see ops.py) — the static-allocation
idea from the paper's GPU port, minus its single global max-|S| buffer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..core.triad_table import TRIAD_TABLE_64

SENTINEL = np.int32(2**30)


def _census_kernel(u_ref, v_ref, n_ref, out_u_ref, in_u_ref, out_v_ref,
                   in_v_ref, nbr_u_ref, nbr_v_ref, table_ref, out_ref):
    u = u_ref[...]  # (B, 1)
    v = v_ref[...]
    n = n_ref[0]
    out_u = out_u_ref[...]  # (B, K)
    in_u = in_u_ref[...]
    out_v = out_v_ref[...]
    in_v = in_v_ref[...]
    nbr_u = nbr_u_ref[...]
    nbr_v = nbr_v_ref[...]

    def member(cand, rows):
        # (B, K) x (B, K) -> (B, K): any-equal along the row tile
        return (cand[:, :, None] == rows[:, None, :]).any(axis=-1)

    valid_u = nbr_u != SENTINEL
    valid_v = nbr_v != SENTINEL
    mu = valid_u & (nbr_u != v)
    mv = valid_v & (nbr_v != u)
    dup = member(nbr_v, nbr_u) & mv
    mv_only = mv & ~dup
    s_size = (mu.sum(axis=1, dtype=jnp.int32)
              + mv_only.sum(axis=1, dtype=jnp.int32))  # (B,)

    # dyad code (paper v0.4: computed once per dyad, 4 probes left per w)
    e_uv = member(v, out_u)[:, 0]
    e_vu = member(u, out_v)[:, 0]
    dyad_code = e_uv.astype(jnp.int32) + 2 * e_vu.astype(jnp.int32)  # (B,)
    pad_dyad = u[:, 0] == SENTINEL

    # candidate triad codes from both neighborhood tiles
    def codes(cand, canon):
        c = dyad_code[:, None]
        c = c + 4 * member(cand, out_u).astype(jnp.int32)
        c = c + 8 * member(cand, in_u).astype(jnp.int32)
        c = c + 16 * member(cand, out_v).astype(jnp.int32)
        c = c + 32 * member(cand, in_v).astype(jnp.int32)
        return jnp.where(canon, c, 0)

    canon_u = mu & (nbr_u > v)
    canon_v = mv_only & ((nbr_v > v) | ((nbr_v > u) & (nbr_v < v)))
    canon_u &= ~pad_dyad[:, None]
    canon_v &= ~pad_dyad[:, None]
    c_u = codes(nbr_u, canon_u)  # (B, K) in [0, 64)
    c_v = codes(nbr_v, canon_v)

    # 64-bin histogram via compare-reduce (VPU), then 16-bin map via MXU
    bins = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 64), 2)
    h = ((c_u[:, :, None] == bins) & canon_u[:, :, None]).sum((0, 1))
    h = h + ((c_v[:, :, None] == bins) & canon_v[:, :, None]).sum((0, 1))
    counts16 = (table_ref[...] @ h[:, None].astype(jnp.float32))[:, 0]

    # dyadic triads: n - |S| - 2 into bin 1 ("012") or 2 ("102")
    dyadic = jnp.where(pad_dyad, 0, n - s_size - 2).astype(jnp.float32)
    is_mut = (dyad_code == 3) & ~pad_dyad
    counts16 = counts16.at[1].add(jnp.where(is_mut, 0.0, dyadic).sum())
    counts16 = counts16.at[2].add(jnp.where(is_mut, dyadic, 0.0).sum())
    out_ref[...] = counts16[None].astype(jnp.int32)


def census_tiles_pallas(u, v, n, out_u, in_u, out_v, in_v, nbr_u, nbr_v,
                        *, block: int = 32, interpret: bool = True,
                        reduce: bool = True):
    """Run the census kernel over (D, K) tiles; returns (16,) partial counts.

    ``interpret=True`` executes the kernel body in Python on CPU (this
    container); on a real TPU pass ``interpret=False``.  ``n`` may be a
    traced scalar (the engine's device-resident path calls this under jit).
    With ``reduce=False`` the raw per-grid-step ``(grid, 16)`` int32
    partials are returned so the caller can fold them into a wider
    accumulator (the engine's hi/lo pair) instead of risking an int32
    overflow in the grid-sum.
    """
    D, K = nbr_u.shape
    assert D % block == 0, (D, block)
    grid = (D // block,)
    # one-hot (16, 64) isomorphism map for the MXU epilogue
    table16 = np.zeros((16, 64), np.float32)
    table16[TRIAD_TABLE_64, np.arange(64)] = 1.0

    row = pl.BlockSpec((block, 1), lambda i: (i, 0))
    tile = pl.BlockSpec((block, K), lambda i: (i, 0))
    full = pl.BlockSpec((16, 64), lambda i: (0, 0))
    scalar = pl.BlockSpec((1,), lambda i: (0,))

    partials = pl.pallas_call(
        _census_kernel,
        grid=grid,
        in_specs=[row, row, scalar, tile, tile, tile, tile, tile, tile, full],
        out_specs=pl.BlockSpec((1, 16), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid[0], 16), jnp.int32),
        interpret=interpret,
    )(u[:, None], v[:, None], jnp.asarray(n, jnp.int32).reshape(1), out_u,
      in_u, out_v, in_v, nbr_u, nbr_v, jnp.asarray(table16))
    if not reduce:
        return partials  # (grid, 16)
    # decoupled-accumulator merge (paper: per-thread-block census arrays)
    return partials.sum(0)
