"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from ..core.triad_table import TRIAD_TABLE_64


def census_tiles_ref(out_u, in_u, out_v, in_v, nbr_u, nbr_v, u, v, n,
                     sentinel=jnp.int32(2**30)):
    """Oracle for the triad-census tile kernel.

    All tile args: (D, K) int32 padded with ``sentinel``; u, v: (D,).
    Returns (16,) int64-safe int32 histogram of dyadic+connected triads
    (null triads come from the closed form outside).
    """

    def member(cand, rows):
        return (cand[:, :, None] == rows[:, None, :]).any(-1)

    valid_u = nbr_u != sentinel
    valid_v = nbr_v != sentinel
    # S = N(u) ∪ N(v) \ {u, v}
    mu = valid_u & (nbr_u != v[:, None])
    mv = valid_v & (nbr_v != u[:, None])
    dup = member(nbr_v, nbr_u) & mv
    mv_only = mv & ~dup
    s_size = mu.sum(1) + mv_only.sum(1)

    e_uv = member(v[:, None], out_u)[:, 0]
    e_vu = member(u[:, None], out_v)[:, 0]
    dyad_code = e_uv.astype(jnp.int32) + 2 * e_vu.astype(jnp.int32)
    dyad_type = jnp.where(dyad_code == 3, 2, 1)
    dyadic = n - s_size - 2

    def codes(cand, canon):
        c = dyad_code[:, None]
        c = c + 4 * member(cand, out_u).astype(jnp.int32)
        c = c + 8 * member(cand, in_u).astype(jnp.int32)
        c = c + 16 * member(cand, out_v).astype(jnp.int32)
        c = c + 32 * member(cand, in_v).astype(jnp.int32)
        t = jnp.asarray(TRIAD_TABLE_64)[c]
        return jnp.where(canon, t, 0), canon

    canon_u = mu & (nbr_u > v[:, None])
    canon_v = mv_only & ((nbr_v > v[:, None]) |
                         ((nbr_v > u[:, None]) & (nbr_v < v[:, None])))
    t_u, m_u = codes(nbr_u, canon_u)
    t_v, m_v = codes(nbr_v, canon_v)
    counts = jnp.zeros(16, jnp.int32)
    counts = counts.at[t_u.reshape(-1)].add(m_u.reshape(-1).astype(jnp.int32))
    counts = counts.at[t_v.reshape(-1)].add(m_v.reshape(-1).astype(jnp.int32))
    counts = counts.at[0].set(0)
    counts = counts + jnp.zeros(16, jnp.int32).at[dyad_type].add(dyadic)
    return counts


def flash_attention_ref(q, k, v, q_pos, kv_pos, window=None):
    """Dense causal (optionally windowed) GQA attention oracle.

    q: (B, T, H, D); k/v: (B, S, Hkv, D); positions: (B, T)/(B, S).
    """
    B, T, H, D = q.shape
    Hkv = k.shape[2]
    qg = q.reshape(B, T, Hkv, H // Hkv, D)
    s = jnp.einsum("btkgd,bskd->bkgts", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    mask = kv_pos[:, None, :] <= q_pos[:, :, None]
    if window is not None:
        mask &= kv_pos[:, None, :] > q_pos[:, :, None] - window
    s = jnp.where(mask[:, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgts,bskd->btkgd", w, v.astype(jnp.float32))
    return o.reshape(B, T, H, D).astype(q.dtype)
