"""Pallas TPU kernels for the perf-critical hot spots.

* triad_census — the paper's inner loop as dense VMEM tile compares
* flash_attention — LM prefill attention with VMEM-resident softmax state

Each kernel ships with ops.py (jit wrapper) and ref.py (pure-jnp oracle);
tests sweep shapes/dtypes in interpret mode against the oracles.
"""
from . import ops, ref  # noqa: F401
