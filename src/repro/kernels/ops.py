"""Jitted wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (this container validates kernel
bodies in interpret mode); on a TPU backend the real kernels run.
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import CSRGraph
from .flash_attention import flash_attention_pallas
from .triad_census import SENTINEL


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, q_pos, kv_pos, *, window=None, chunk=128,
                    interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return flash_attention_pallas(q, k, v, q_pos, kv_pos, window=window,
                                  block_q=chunk, block_kv=chunk,
                                  interpret=interpret)


# ----------------------------------------------------------------------------
# triad census: tile construction + degree-bucketed kernel launch
# ----------------------------------------------------------------------------

def _pad_rows(ptr, idx, rows, K):
    """(len(rows), K) tile of CSR rows padded with SENTINEL (host numpy)."""
    deg = ptr[rows + 1] - ptr[rows]
    out = np.full((len(rows), K), SENTINEL, dtype=np.int32)
    j = np.arange(K)
    m = j[None, :] < deg[:, None]
    pos = np.minimum(ptr[rows][:, None] + j[None, :], len(idx) - 1)
    vals = idx[pos]
    out[m] = vals[m]
    return out


def build_in_csr(g: CSRGraph) -> tuple[np.ndarray, np.ndarray]:
    """Transpose CSR for the IsEdge(w, u) -> w in IN(u) reformulation.

    Built once per graph and reused across streaming chunks (see
    :mod:`repro.engine.backends`).
    """
    out_ptr = np.asarray(g.arrays.out_ptr)
    out_idx = np.asarray(g.arrays.out_idx)
    rows = np.repeat(np.arange(g.n, dtype=np.int64), np.diff(out_ptr))
    # lexsort: primary key = in-row (out_idx), secondary = in-col (rows),
    # so the transposed CSR comes out row-sorted with sorted columns.
    order = np.lexsort((rows, out_idx))
    in_rows, in_cols = out_idx[order].astype(np.int64), rows[order]
    in_ptr = np.zeros(g.n + 1, np.int64)
    np.add.at(in_ptr, in_rows + 1, 1)
    in_ptr = np.cumsum(in_ptr)
    return in_ptr, in_cols.astype(np.int32)


@jax.jit
def build_in_csr_device(out_ptr: jax.Array, out_idx: jax.Array):
    """Device-side :func:`build_in_csr`: transpose CSR from padded arrays.

    ``out_ptr``/``out_idx`` are the bucket-padded directed CSR
    (``CensusPlan.padded_arrays``); the true arc count is ``out_ptr[-1]``
    because padded ptr rows repeat the last offset.  Returns
    ``(in_ptr, in_idx)`` with the same padded shapes — padded ``in_idx``
    tail entries are inert (no real row's ptr range reaches them).  Built
    once per run, on device; no host round trip.
    """
    M = out_idx.shape[0]
    n = out_ptr.shape[0] - 1
    pos = jnp.arange(M, dtype=jnp.int32)
    rows = (jnp.searchsorted(out_ptr, pos, side="right") - 1).astype(jnp.int32)
    m = out_ptr[-1]
    # padding entries get sort key n (past every real row) so they land at
    # the array tail and outside every in_ptr range.
    cols_key = jnp.where(pos < m, out_idx, n)
    order = jnp.argsort(cols_key)  # stable: within-row cols stay sorted
    in_idx = rows[order]
    counts = jnp.zeros(n + 1, jnp.int32).at[cols_key].add(1)[:n]
    in_ptr = jnp.concatenate([jnp.zeros(1, jnp.int32),
                              jnp.cumsum(counts).astype(jnp.int32)])
    return in_ptr, in_idx


def _gather_rows(ptr, idx, rows, row_valid, K: int):
    """(B, K) SENTINEL-padded tile of CSR rows — the device ``_pad_rows``."""
    r = jnp.where(row_valid, rows, 0)
    start = ptr[r]
    deg = ptr[r + 1] - start
    j = jnp.arange(K, dtype=jnp.int32)
    pos = jnp.clip(start[:, None] + j[None, :], 0, idx.shape[0] - 1)
    w = idx[pos]
    live = row_valid[:, None] & (j[None, :] < deg[:, None])
    return jnp.where(live, w, SENTINEL)


@functools.partial(jax.jit, static_argnames=("K",))
def gather_tiles_device(arrays, u: jax.Array, v: jax.Array,
                        valid: jax.Array, *, K: int):
    """Device-side :func:`build_tiles`: all six (B, K) tiles in one trace.

    ``arrays`` is a :class:`repro.core.graph.GraphArrays` whose
    ``in_ptr``/``in_idx`` transpose CSR is populated (see
    :func:`build_in_csr_device`).  Rows with ``valid == False`` come back
    all-SENTINEL, matching the host path's blanked padding tiles.
    """
    return dict(
        out_u=_gather_rows(arrays.out_ptr, arrays.out_idx, u, valid, K),
        in_u=_gather_rows(arrays.in_ptr, arrays.in_idx, u, valid, K),
        out_v=_gather_rows(arrays.out_ptr, arrays.out_idx, v, valid, K),
        in_v=_gather_rows(arrays.in_ptr, arrays.in_idx, v, valid, K),
        nbr_u=_gather_rows(arrays.nbr_ptr, arrays.nbr_idx, u, valid, K),
        nbr_v=_gather_rows(arrays.nbr_ptr, arrays.nbr_idx, v, valid, K),
    )


def build_tiles(g: CSRGraph, u: np.ndarray, v: np.ndarray, K: int,
                in_csr: tuple[np.ndarray, np.ndarray] | None = None):
    """All six (D, K) neighborhood tiles for a dyad batch."""
    out_ptr = np.asarray(g.arrays.out_ptr)
    out_idx = np.asarray(g.arrays.out_idx)
    nbr_ptr = np.asarray(g.arrays.nbr_ptr)
    nbr_idx = np.asarray(g.arrays.nbr_idx)
    in_ptr, in_idx = in_csr if in_csr is not None else build_in_csr(g)
    return dict(
        out_u=_pad_rows(out_ptr, out_idx, u, K),
        in_u=_pad_rows(in_ptr, in_idx, u, K),
        out_v=_pad_rows(out_ptr, out_idx, v, K),
        in_v=_pad_rows(in_ptr, in_idx, v, K),
        nbr_u=_pad_rows(nbr_ptr, nbr_idx, u, K),
        nbr_v=_pad_rows(nbr_ptr, nbr_idx, v, K),
    )


def triad_census_kernel(g: CSRGraph, *, block: int = 32,
                        buckets: tuple = (32, 128, 512),
                        interpret=None) -> np.ndarray:
    """Full 16-type census via the Pallas kernel, degree-bucketed.

    .. deprecated:: use ``repro.engine.compile_census`` with
       ``CensusConfig(backend="pallas")`` — this shim forwards there.
       Returns (16,) int64 counts.
    """
    from ..engine import CensusConfig, compile_census

    warnings.warn(
        "repro.kernels.ops.triad_census_kernel is deprecated; use "
        "repro.engine.compile_census with CensusConfig(backend='pallas')",
        DeprecationWarning, stacklevel=2)
    cfg = CensusConfig(backend="pallas", block=block, buckets=tuple(buckets),
                       interpret=interpret)
    return compile_census(g, cfg).run(g).counts
