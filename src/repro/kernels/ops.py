"""Jitted wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (this container validates kernel
bodies in interpret mode); on a TPU backend the real kernels run.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.census import canonical_dyads
from ..core.graph import CSRGraph
from .flash_attention import flash_attention_pallas
from .triad_census import SENTINEL, census_tiles_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, q_pos, kv_pos, *, window=None, chunk=128,
                    interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return flash_attention_pallas(q, k, v, q_pos, kv_pos, window=window,
                                  block_q=chunk, block_kv=chunk,
                                  interpret=interpret)


# ----------------------------------------------------------------------------
# triad census: tile construction + degree-bucketed kernel launch
# ----------------------------------------------------------------------------

def _pad_rows(ptr, idx, rows, K):
    """(len(rows), K) tile of CSR rows padded with SENTINEL (host numpy)."""
    deg = ptr[rows + 1] - ptr[rows]
    out = np.full((len(rows), K), SENTINEL, dtype=np.int32)
    j = np.arange(K)
    m = j[None, :] < deg[:, None]
    pos = np.minimum(ptr[rows][:, None] + j[None, :], len(idx) - 1)
    vals = idx[pos]
    out[m] = vals[m]
    return out


def build_tiles(g: CSRGraph, u: np.ndarray, v: np.ndarray, K: int):
    """All six (D, K) neighborhood tiles for a dyad batch."""
    out_ptr = np.asarray(g.arrays.out_ptr)
    out_idx = np.asarray(g.arrays.out_idx)
    nbr_ptr = np.asarray(g.arrays.nbr_ptr)
    nbr_idx = np.asarray(g.arrays.nbr_idx)
    # in-CSR (transpose) for the IsEdge(w, u) -> w in IN(u) reformulation
    rows = np.repeat(np.arange(g.n, dtype=np.int64), np.diff(out_ptr))
    # lexsort: primary key = in-row (out_idx), secondary = in-col (rows),
    # so the transposed CSR comes out row-sorted with sorted columns.
    order = np.lexsort((rows, out_idx))
    in_rows, in_cols = out_idx[order].astype(np.int64), rows[order]
    in_ptr = np.zeros(g.n + 1, np.int64)
    np.add.at(in_ptr, in_rows + 1, 1)
    in_ptr = np.cumsum(in_ptr)
    in_idx = in_cols.astype(np.int32)
    return dict(
        out_u=_pad_rows(out_ptr, out_idx, u, K),
        in_u=_pad_rows(in_ptr, in_idx, u, K),
        out_v=_pad_rows(out_ptr, out_idx, v, K),
        in_v=_pad_rows(in_ptr, in_idx, v, K),
        nbr_u=_pad_rows(nbr_ptr, nbr_idx, u, K),
        nbr_v=_pad_rows(nbr_ptr, nbr_idx, v, K),
    )


def triad_census_kernel(g: CSRGraph, *, block: int = 32,
                        buckets: tuple = (32, 128, 512),
                        interpret=None) -> np.ndarray:
    """Full 16-type census via the Pallas kernel, degree-bucketed.

    Dyads are routed to the smallest tile width K >= max involved degree
    (the beyond-paper padding-waste optimization); the final bucket uses
    the graph's max degree.  Returns (16,) int64 counts.
    """
    interpret = _default_interpret() if interpret is None else interpret
    u, v = canonical_dyads(g)
    deg = np.asarray(g.arrays.nbr_deg)
    out_deg = np.diff(np.asarray(g.arrays.out_ptr))
    # a dyad's tile must hold nbr/out/in rows of u and v
    need = np.maximum(deg[u], deg[v])
    need = np.maximum(need, np.maximum(out_deg[u], out_deg[v]))
    ks = sorted({min(max(int(k), 1), max(g.max_deg, 1)) for k in buckets}
                | {max(g.max_deg, 1)})
    counts = np.zeros(16, np.int64)
    assigned = np.zeros(len(u), bool)
    for K in ks:
        sel = (~assigned) & (need <= K)
        assigned |= sel
        if not sel.any():
            continue
        uu, vv = u[sel], v[sel]
        pad = (-len(uu)) % block
        if pad:
            uu = np.concatenate([uu, np.full(pad, SENTINEL, np.int32)])
            vv = np.concatenate([vv, np.full(pad, SENTINEL, np.int32)])
        tiles = build_tiles(g, np.clip(uu, 0, g.n - 1).astype(np.int64),
                            np.clip(vv, 0, g.n - 1).astype(np.int64), K)
        if pad:  # padded dyads: blank their tiles
            for t in tiles.values():
                t[-pad:] = SENTINEL
        part = census_tiles_pallas(
            jnp.asarray(uu), jnp.asarray(vv), g.n,
            *(jnp.asarray(tiles[k]) for k in
              ("out_u", "in_u", "out_v", "in_v", "nbr_u", "nbr_v")),
            block=block, interpret=interpret)
        counts += np.asarray(part, dtype=np.int64)
    total = g.n * (g.n - 1) * (g.n - 2) // 6
    counts[0] = total - counts.sum()
    return counts
