"""Jitted wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (this container validates kernel
bodies in interpret mode); on a TPU backend the real kernels run.
"""
from __future__ import annotations

import jax
import numpy as np

from ..core.graph import CSRGraph
from .flash_attention import flash_attention_pallas
from .triad_census import SENTINEL


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, q_pos, kv_pos, *, window=None, chunk=128,
                    interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return flash_attention_pallas(q, k, v, q_pos, kv_pos, window=window,
                                  block_q=chunk, block_kv=chunk,
                                  interpret=interpret)


# ----------------------------------------------------------------------------
# triad census: tile construction + degree-bucketed kernel launch
# ----------------------------------------------------------------------------

def _pad_rows(ptr, idx, rows, K):
    """(len(rows), K) tile of CSR rows padded with SENTINEL (host numpy)."""
    deg = ptr[rows + 1] - ptr[rows]
    out = np.full((len(rows), K), SENTINEL, dtype=np.int32)
    j = np.arange(K)
    m = j[None, :] < deg[:, None]
    pos = np.minimum(ptr[rows][:, None] + j[None, :], len(idx) - 1)
    vals = idx[pos]
    out[m] = vals[m]
    return out


def build_in_csr(g: CSRGraph) -> tuple[np.ndarray, np.ndarray]:
    """Transpose CSR for the IsEdge(w, u) -> w in IN(u) reformulation.

    Built once per graph and reused across streaming chunks (see
    :mod:`repro.engine.backends`).
    """
    out_ptr = np.asarray(g.arrays.out_ptr)
    out_idx = np.asarray(g.arrays.out_idx)
    rows = np.repeat(np.arange(g.n, dtype=np.int64), np.diff(out_ptr))
    # lexsort: primary key = in-row (out_idx), secondary = in-col (rows),
    # so the transposed CSR comes out row-sorted with sorted columns.
    order = np.lexsort((rows, out_idx))
    in_rows, in_cols = out_idx[order].astype(np.int64), rows[order]
    in_ptr = np.zeros(g.n + 1, np.int64)
    np.add.at(in_ptr, in_rows + 1, 1)
    in_ptr = np.cumsum(in_ptr)
    return in_ptr, in_cols.astype(np.int32)


def build_tiles(g: CSRGraph, u: np.ndarray, v: np.ndarray, K: int,
                in_csr: tuple[np.ndarray, np.ndarray] | None = None):
    """All six (D, K) neighborhood tiles for a dyad batch."""
    out_ptr = np.asarray(g.arrays.out_ptr)
    out_idx = np.asarray(g.arrays.out_idx)
    nbr_ptr = np.asarray(g.arrays.nbr_ptr)
    nbr_idx = np.asarray(g.arrays.nbr_idx)
    in_ptr, in_idx = in_csr if in_csr is not None else build_in_csr(g)
    return dict(
        out_u=_pad_rows(out_ptr, out_idx, u, K),
        in_u=_pad_rows(in_ptr, in_idx, u, K),
        out_v=_pad_rows(out_ptr, out_idx, v, K),
        in_v=_pad_rows(in_ptr, in_idx, v, K),
        nbr_u=_pad_rows(nbr_ptr, nbr_idx, u, K),
        nbr_v=_pad_rows(nbr_ptr, nbr_idx, v, K),
    )


def triad_census_kernel(g: CSRGraph, *, block: int = 32,
                        buckets: tuple = (32, 128, 512),
                        interpret=None) -> np.ndarray:
    """Full 16-type census via the Pallas kernel, degree-bucketed.

    .. deprecated:: use ``repro.engine.compile_census`` with
       ``CensusConfig(backend="pallas")`` — this shim forwards there.
       Returns (16,) int64 counts.
    """
    from ..engine import CensusConfig, compile_census

    cfg = CensusConfig(backend="pallas", block=block, buckets=tuple(buckets),
                       interpret=interpret)
    return compile_census(g, cfg).run(g).counts
