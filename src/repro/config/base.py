"""Config system: immutable dataclasses describing models, shapes, and runs.

Every assigned architecture is a :class:`ModelConfig` in
:mod:`repro.configs`, selectable by ``--arch <id>`` in the launchers.  The
four assigned input shapes are the :data:`SHAPES` table.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    first_dense_layers: int = 0  # leading dense layers (deepseek-v2 uses 1)
    router_aux_weight: float = 0.001


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4
    chunk: int = 64
    n_groups: int = 1
    attn_every: int = 6  # zamba2: shared attention block every k SSM layers


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64
    gate_lora: int = 32
    chunk: int = 32


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: Optional[int] = None
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # modality frontend stubs (assignment: backbone only)
    n_prefix_embeds: int = 0  # vlm: precomputed patch embeddings prepended
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.rwkv is not None

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k decode (assignment rule)."""
        return (
            self.rwkv is not None
            or self.ssm is not None
            or self.sliding_window is not None
        )

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline bookkeeping)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.rwkv is not None:
            # time-mix (r,k,v,w,g,o) + channel-mix, LoRA extras approximated
            per_layer = 6 * d * d + 2 * d * self.d_ff + 2 * d * self.rwkv.decay_lora
        elif self.ssm is not None:
            di = self.ssm.expand * d
            conv_dim = di + 2 * self.ssm.n_groups * self.ssm.d_state
            per_layer = d * (2 * di + 2 * self.ssm.n_groups * self.ssm.d_state
                             + di // self.ssm.head_dim) + di * d + conv_dim * self.ssm.conv_width
            n_attn = self.n_layers // self.ssm.attn_every
            attn = 2 * d * (n_q * hd) + 2 * d * (n_kv * hd) + 3 * d * self.d_ff
            return emb + per_layer * self.n_layers + attn + n_attn * 0
        elif self.mla is not None:
            m = self.mla
            per_layer = (
                d * m.q_lora_rank
                + m.q_lora_rank * n_q * (m.nope_head_dim + m.rope_head_dim)
                + d * (m.kv_lora_rank + m.rope_head_dim)
                + m.kv_lora_rank * n_q * (m.nope_head_dim + m.v_head_dim)
                + n_q * m.v_head_dim * d
            )
        else:
            per_layer = d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d
        if self.moe is not None:
            mo = self.moe
            moe_layers = self.n_layers - mo.first_dense_layers
            ffn = (
                moe_layers * mo.n_experts * 3 * d * mo.d_ff_expert
                + moe_layers * mo.n_shared_experts * 3 * d * mo.d_ff_shared
                + mo.first_dense_layers * 3 * d * self.d_ff
                + moe_layers * mo.n_experts * 0
            )
        elif self.rwkv is None and self.ssm is None:
            ffn = self.n_layers * 3 * d * self.d_ff
        else:
            ffn = 0 if self.ssm is not None else 0
        return emb + per_layer * self.n_layers + ffn

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        mo = self.moe
        full = self.param_count()
        moe_layers = self.n_layers - mo.first_dense_layers
        all_experts = moe_layers * mo.n_experts * 3 * self.d_model * mo.d_ff_expert
        active = moe_layers * mo.top_k * 3 * self.d_model * mo.d_ff_expert
        return full - all_experts + active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: Literal["train", "prefill", "decode"]
    seq_len: int
    global_batch: int


#: The assignment's four shape cells.
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Knobs orthogonal to the architecture (the perf-iteration surface)."""

    attention_impl: Literal["dense", "chunked", "chunked_causal", "pallas"] = "chunked_causal"
    attention_chunk: int = 1024
    remat: Literal["none", "full", "dots"] = "full"
    remat_attention: bool = False  # recompute flash rows in backward (no
    # per-iteration score stash); §Perf iteration knob
    scan_layers: bool = True
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    grad_compression: Literal["none", "int8"] = "none"
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # sharding toggles (hillclimb surface)
    fsdp_axis: Optional[str] = "data"  # shard weights over this axis too
    seq_shard_decode: bool = True  # shard long-decode KV over batch axes
    act_shard_model: bool = False  # Megatron-SP style activation stash shard
    microbatch: Optional[int] = None  # gradient-accumulation steps
    moe_groups: Optional[int] = None  # GShard grouped dispatch (None = flat)
    moe_dense_eval: bool = False  # tiny-expert fast path: all experts, no dispatch
