from .base import (MLAConfig, MoEConfig, ModelConfig, RWKVConfig, RunConfig,
                   SSMConfig, ShapeConfig, SHAPES)
from .registry import get_config, list_configs, register

__all__ = [
    "MLAConfig", "MoEConfig", "ModelConfig", "RWKVConfig", "RunConfig",
    "SSMConfig", "ShapeConfig", "SHAPES", "get_config", "list_configs",
    "register",
]
