"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib
from typing import Callable

from .base import ModelConfig

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}

#: assigned architecture ids -> config module under repro.configs
ARCH_MODULES = {
    "zamba2-1.2b": "zamba2_1p2b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "qwen1.5-4b": "qwen1p5_4b",
    "qwen3-4b": "qwen3_4b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "pixtral-12b": "pixtral_12b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "rwkv6-3b": "rwkv6_3b",
    "musicgen-large": "musicgen_large",
}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str, *, smoke: bool = False) -> ModelConfig:
    if name not in _REGISTRY:
        mod = ARCH_MODULES.get(name)
        if mod is None:
            raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCH_MODULES)}")
        importlib.import_module(f"repro.configs.{mod}")
    maker = _REGISTRY[f"{name}:smoke"] if smoke else _REGISTRY[name]
    return maker()


def list_configs() -> list[str]:
    return sorted(ARCH_MODULES)
