"""Serving layers: LM token decode + the batched multi-analytic graph
service.

``CensusService`` (see :mod:`repro.serve.census_service`) is the graph
fleet front door: requests — each naming the GraphOp analytics it wants —
are grouped by (plan-cache bucket, ops) and executed as vmapped
fixed-shape fused batches through ``Plan.run_batch``.
"""
from .census_service import CensusCompletion, CensusService, ServiceConfig
from .decode import make_prefill_step, make_serve_step

__all__ = ["CensusCompletion", "CensusService", "ServiceConfig",
           "make_prefill_step", "make_serve_step"]
