"""Serving layers: LM token decode + the batched multi-analytic graph
service.

``CensusService`` (see :mod:`repro.serve.census_service`) is the graph
fleet front door: requests — each naming the GraphOp analytics it wants —
are grouped by (plan-cache bucket, ops) and executed as vmapped
fixed-shape fused batches through ``Plan.run_batch``.  The service is
hardened for long-running fleets: ``ServiceConfig(max_pending=...,
reject_policy=...)`` admission control (typed :class:`AdmissionError`),
clockless flush-round deadlines (:class:`DeadlineExceeded` completions),
member-wise isolation of poison graphs inside a batch, and
``stats()["health"]`` recovery counters.
"""
from .census_service import (AdmissionError, CensusCompletion,
                             CensusService, DeadlineExceeded, ServiceConfig)
from .decode import make_prefill_step, make_serve_step

__all__ = ["AdmissionError", "CensusCompletion", "CensusService",
           "DeadlineExceeded", "ServiceConfig",
           "make_prefill_step", "make_serve_step"]
