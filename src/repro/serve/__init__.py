"""Serving layers: LM token decode + the batched multi-graph census service.

``CensusService`` (see :mod:`repro.serve.census_service`) is the census
fleet front door: requests are grouped by plan-cache bucket and executed
as vmapped fixed-shape batches through ``CensusPlan.run_batch``.
"""
from .census_service import CensusCompletion, CensusService, ServiceConfig
from .decode import make_prefill_step, make_serve_step

__all__ = ["CensusCompletion", "CensusService", "ServiceConfig",
           "make_prefill_step", "make_serve_step"]
