"""Batched multi-graph, multi-analytic serving (the fleet front door).

The engine's plan cache already amortizes *compilation* across same-shape
graphs; this layer amortizes *dispatch*.  A :class:`CensusService` accepts
a stream of :class:`~repro.core.graph.CSRGraph` requests — each optionally
naming the :class:`~repro.engine.GraphOp` analytics it wants — groups them
by (:class:`~repro.engine.GraphMeta` bucket, ops) key, and executes each
group as ONE vmapped fixed-shape batch through ``Plan.run_batch``: B
requests for one chunk schedule of dispatches and one device→host
transfer, every requested analytic computed in the same fused pass.  That
is the workload shape of triadic analysis over graph *collections* (Chin
et al., "Scalable Triadic Analysis of Large-Scale Graphs"): many small
same-shape graphs and a family of analyses, not one giant kernel launch.

Design properties:

  * **Deterministic, clockless batching** — groups flush when they reach
    ``max_batch`` or when ``max_wait_requests`` newer requests have been
    submitted since the group's oldest member (bounded staleness without
    wall-clock timers, so behavior is exactly reproducible in tests).
  * **Out-of-order completion, stable ids** — ``submit`` returns a
    monotonically increasing request id; completions surface in batch
    flush order, each tagged with its id, bucket, and ops.
  * **Per-bucket stats** — batches formed, occupancy, host syncs, and a
    per-ops request breakdown: the numbers that tell you whether the
    fleet is actually batching.

Synchronous by construction: batches execute inside ``submit``/``flush``
on the caller's thread (device work itself is still async under the
engine's double-buffered dispatcher).  One exception: when the engine
config selects the dynamic executor schedule
(``CensusConfig(schedule="dynamic")``), :meth:`CensusService.flush`
drains multi-group backlogs through the executor device pool
*concurrently* — each (bucket, ops) group runs on its own thread, its
chunks work-queued over the shared pool, so different buckets occupy
different devices at the same time.  Per-device chunk occupancy is
surfaced in :meth:`CensusService.stats`.

Beyond the stateless request stream, the service also runs **subscribed
sessions** — the evolving-graph mode (Chin et al.'s workload is edge
traffic, not whole-graph resubmission): :meth:`CensusService.subscribe`
pins a graph and its ops, clients stream
:meth:`~CensusService.mutate`\\ (session,
:class:`~repro.core.delta.GraphDelta`) and read fresh counts with
:meth:`~CensusService.poll`\\ (session) at any time.  Each mutation rides
``Plan.apply_delta`` — work proportional to the mutation footprint, one
device→host sync — falling back to a full recompute past the
``delta_threshold`` cost model, and transparently recompiling (plan
cache — other sessions in the same bucket share it) when a mutation
outgrows the session plan's metadata buckets.  Per-session delta / full
/ recompile counters surface in :meth:`CensusService.stats`.
"""
from __future__ import annotations

import dataclasses
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, Iterable, List, NamedTuple, Optional, Tuple

from ..core.delta import GraphDelta, apply_delta_csr
from ..core.graph import CSRGraph
from ..engine import CensusConfig, GraphMeta, PlanShapeError, compile
from ..engine.ops import get_op, resolve_ops

__all__ = ["CensusCompletion", "CensusService", "ServiceConfig"]

_DEFAULT_OPS = ("triad_census",)


def _normalize_ops(ops) -> Tuple[str, ...]:
    """Per-request ops spec -> validated tuple of registered op names.

    Validation happens here, at submit time, so a bad spec (typo'd name,
    unregistered instance) rejects the one request instead of surfacing
    at flush time and taking its whole batch group down with it.  Groups
    are keyed (and flushed) by *name*, so a GraphOp instance is accepted
    only if it IS the registered op of that name — a name-colliding
    unregistered instance must not be silently swapped for the
    registry's implementation."""
    if ops is None:
        return _DEFAULT_OPS
    names = []
    for op in resolve_ops(ops):
        if get_op(op.name) is not op:  # KeyError if the name is unknown
            raise ValueError(
                f"service requests resolve ops by name at flush time, but "
                f"the submitted {op.name!r} instance is not the registered "
                f"one — register_op(...) it (overwrite=True to replace the "
                f"existing registration) before submitting")
        names.append(op.name)
    return tuple(names)


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Batching policy for a :class:`CensusService`.

    Attributes:
        max_batch: flush a group as soon as it holds this many requests —
            the vmapped batch width the service aims for.  Larger batches
            amortize dispatch further but retrace the batched unit once
            per new (power-of-two-padded) width.
        max_wait_requests: bounded-staleness valve.  A partial group is
            force-flushed once this many *other-group* requests have
            been submitted since the group's oldest member — a rare
            bucket can never wait forever behind hot ones, while a hot
            bucket's own burst is still allowed to fill to
            ``max_batch``.  ``0`` disables waiting entirely: every
            submit flushes immediately (B = 1, the unbatched baseline).
            Counted in requests, not seconds, so tests are
            deterministic.
        census: the :class:`~repro.engine.EngineConfig` every request
            executes under — together with the request's (bucket, ops)
            key it pins the plan-cache entry, so one service maps to at
            most one cached plan per (bucket, ops) group.
        max_sessions: cap on concurrently subscribed evolving-graph
            sessions (:meth:`CensusService.subscribe`).  Each live
            session pins its current graph, raw accumulator bins and a
            plan-cache reference, so the cap bounds the service's
            resident state; ``subscribe`` past it raises until a session
            is :meth:`~CensusService.unsubscribe`\\ d.
    """

    max_batch: int = 8
    max_wait_requests: int = 64
    census: CensusConfig = dataclasses.field(default_factory=CensusConfig)
    max_sessions: int = 64

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.max_wait_requests < 0:
            raise ValueError("max_wait_requests must be >= 0")
        if self.max_sessions < 1:
            raise ValueError("max_sessions must be >= 1")


class CensusCompletion(NamedTuple):
    """One finished request: the id ``submit`` returned, its result, the
    metadata bucket it was batched under, and the ops it ran.  For a
    single-op request (the default census-only case) ``result`` is that
    op's bare result object — a ``CensusResult`` for ``triad_census`` —
    and for a multi-op request it is the fused ``{op_name: result}``
    dict."""

    request_id: int
    result: Any
    meta: GraphMeta
    ops: Tuple[str, ...] = _DEFAULT_OPS


@dataclasses.dataclass
class _Session:
    """One subscribed evolving graph: its current state + plan + counters."""

    graph: CSRGraph
    ops: Tuple[str, ...]
    plan: Any
    raw: Any  # (total_bins,) int64 — the plan's raw fused accumulator
    mutations: int = 0
    deltas: int = 0      # mutations served by the affected-subset path
    fulls: int = 0       # mutations that fell back to a full recompute
    recompiles: int = 0  # mutations that outgrew the plan's buckets


class CensusService:
    """Plan-cache-aware batched serving over a mixed-analytic request
    stream.

    ::

        svc = CensusService(ServiceConfig(max_batch=8,
                                          census=CensusConfig(backend="xla")))
        rid = svc.submit(graph)                        # census request
        rid2 = svc.submit(graph, ops=("triad_census",
                                      "degree_stats")) # fused multi-op
        done = svc.flush()             # force-run all partial groups
        for c in done:                 # CensusCompletion, flush order
            ...

    Requests are grouped by (graph bucket, ops): a census-only fleet and
    a multi-analytic fleet over the same graphs batch separately (they
    run different fused plans), but everything inside a group rides one
    vmapped pass.  ``mesh`` is forwarded to the engine for the
    distributed backend; leave ``None`` for the default single-host mesh.
    """

    def __init__(self, config: Optional[ServiceConfig] = None, *, mesh=None):
        self.config = config or ServiceConfig()
        self.mesh = mesh
        # (meta, ops) -> [(rid, graph)] / oldest rid
        self._pending: Dict[tuple, list] = {}
        self._first_seq: Dict[tuple, int] = {}
        self._completed: List[CensusCompletion] = []
        self._seq = 0
        self._bucket_stats: Dict[GraphMeta, dict] = {}
        self._device_chunks: Dict[int, int] = {}
        self._sessions: Dict[int, _Session] = {}
        self._session_seq = 0

    # -- request path --------------------------------------------------------

    def submit(self, graph: CSRGraph, ops=None) -> int:
        """Queue one analytic request; returns its stable request id.

        ``ops`` names the :class:`~repro.engine.GraphOp` set to run — a
        name, a sequence of names, or ``None`` for the census-only
        default.  If the request fills its (bucket, ops) group to
        ``max_batch``, the group executes immediately (synchronously);
        any group gone stale under ``max_wait_requests`` is flushed too.
        Completions are held until :meth:`poll`.
        """
        rid = self._seq
        self._seq += 1
        ops_t = _normalize_ops(ops)
        meta = GraphMeta.from_graph(graph, k=self.config.census.k)
        key = (meta, ops_t)
        group = self._pending.setdefault(key, [])
        if not group:
            self._first_seq[key] = rid
        group.append((rid, graph))
        st = self._bucket_stats.setdefault(
            meta, dict(requests=0, batches=0, batched_graphs=0,
                       host_syncs=0, chunks=0, by_ops={}))
        st["requests"] += 1
        st["by_ops"][ops_t] = st["by_ops"].get(ops_t, 0) + 1
        if len(group) >= self.config.max_batch:
            self._flush_group(key)
        # staleness: count only OTHER groups' arrivals since a group's
        # oldest member — a hot group's own burst must still be allowed
        # to fill to max_batch.
        for stale in [k for k, s in self._first_seq.items()
                      if (self._seq - s - len(self._pending[k])
                          >= self.config.max_wait_requests)]:
            self._flush_group(stale)
        return rid

    def poll(self, session: Optional[int] = None):
        """Without arguments: drain and return completions accumulated
        since the last poll (order is batch flush order — generally NOT
        submission order; match on ``request_id``).

        With a ``session`` id (from :meth:`subscribe`): the subscribed
        graph's fresh analytics — finalized from the session's cached raw
        accumulator bins, so polling costs host-side closed forms only,
        no device work.  Single-op sessions return the bare result object
        (a ``CensusResult`` for the census default), multi-op sessions
        the ``{op_name: result}`` dict — same unwrapping as request
        completions."""
        if session is not None:
            return self._session_results(self._session(session))
        out, self._completed = self._completed, []
        return out

    # -- subscribed evolving-graph sessions ----------------------------------

    def _session(self, session: int) -> _Session:
        try:
            return self._sessions[session]
        except KeyError:
            raise KeyError(f"unknown session {session!r}; live sessions: "
                           f"{sorted(self._sessions)}") from None

    def _session_results(self, s: _Session):
        results = s.plan.layout.finalize(s.raw, s.graph)
        return results[s.ops[0]] if len(s.ops) == 1 else results

    def subscribe(self, graph: CSRGraph, ops=None) -> int:
        """Pin an evolving graph; returns its session id.

        The session compiles (or reuses from the plan cache) the fused
        plan for ``(graph bucket, ops)``, runs one full pass to seed the
        raw accumulator state, and is then ready to take
        :meth:`mutate` streams; :meth:`poll`\\ (session) reads fresh
        counts at any time.  ``ops`` follows :meth:`submit`'s convention
        (``None`` = census only).  Raises once
        ``ServiceConfig.max_sessions`` sessions are live."""
        ops_t = _normalize_ops(ops)
        if len(self._sessions) >= self.config.max_sessions:
            raise RuntimeError(
                f"session limit reached (max_sessions="
                f"{self.config.max_sessions}); unsubscribe() a session "
                "before subscribing another graph")
        plan = compile(graph, ops_t, self.config.census, mesh=self.mesh)
        sid = self._session_seq
        self._session_seq += 1
        self._sessions[sid] = _Session(graph=graph, ops=ops_t, plan=plan,
                                       raw=plan.run_raw(graph))
        return sid

    def mutate(self, session: int, delta: GraphDelta) -> dict:
        """Apply one mutation batch to a subscribed graph.

        Rides ``Plan.apply_delta``: the affected-subset correction (work
        proportional to the delta's footprint, ONE device→host sync) when
        the mutation is local enough, the plan's full pass otherwise
        (``delta_threshold`` cost model) — results are bit-identical
        either way.  A mutation that outgrows the session plan's metadata
        buckets (degree or arc-count growth past the bucketized shape)
        transparently recompiles through the plan cache at the new shape
        and reseeds with one full pass.  Returns an ack dict: ``mode``
        (``"delta"`` | ``"full"`` | ``"recompile"``),
        ``affected_fraction``, and the new ``n`` / ``m``; read the fresh
        counts with :meth:`poll`\\ (session)."""
        s = self._session(session)
        try:
            out = s.plan.apply_delta(s.graph, delta, s.raw)
            s.graph, s.raw = out.graph, out.raw
            mode, frac = out.mode, out.affected_fraction
            if mode == "delta":
                s.deltas += 1
            else:
                s.fulls += 1
        except PlanShapeError:
            g_new = apply_delta_csr(s.graph, delta)
            s.plan = compile(g_new, s.ops, self.config.census,
                             mesh=self.mesh)
            s.graph, s.raw = g_new, s.plan.run_raw(g_new)
            s.recompiles += 1
            mode, frac = "recompile", 1.0
        s.mutations += 1
        return dict(session=session, mode=mode, affected_fraction=frac,
                    n=s.graph.n, m=s.graph.m)

    def unsubscribe(self, session: int):
        """End a session, freeing its ``max_sessions`` slot; returns the
        final analytics (same shape :meth:`poll`\\ (session) returns)."""
        s = self._session(session)
        del self._sessions[session]
        return self._session_results(s)

    def flush(self) -> List[CensusCompletion]:
        """Execute every pending partial group, then drain completions.

        Under the engine's dynamic executor schedule a multi-group
        backlog drains **concurrently**: every group's plan is compiled
        up front (the plan cache is touched only from this thread), then
        each group executes on its own thread, its chunks work-queued
        over the shared executor device pool — different buckets land on
        different devices at the same time.  Results and completion
        order are identical to the sequential drain (integer arithmetic;
        groups are recorded in submission order)."""
        keys = list(self._pending)
        if len(keys) > 1 and self.config.census.schedule == "dynamic":
            # compile every plan BEFORE popping any group (the plan cache
            # is touched only from this thread, and a compile failure
            # must leave every request pending, not dropped).
            plans = {key: compile(key[0], key[1], self.config.census,
                                  mesh=self.mesh) for key in keys}
            jobs = []
            for key in keys:
                group = self._pending.pop(key)
                self._first_seq.pop(key)
                jobs.append((key, group))
            # cap group concurrency at the executor pool width: more
            # flush threads than devices only oversubscribes the pool
            # (each group's executor spawns its own per-device workers)
            # and multiplies peak device memory by the group count.
            width = max(p.executor.n_devices for p in plans.values())
            with ThreadPoolExecutor(
                    max_workers=min(len(jobs), max(width, 1))) as pool:
                futs = [pool.submit(self._execute_group, plans[key], group)
                        for key, group in jobs]
                outs = [f.result() if not f.exception() else f.exception()
                        for f in futs]
            # record every group that finished, THEN surface the first
            # failure — a bad group must not discard its peers' results.
            error = None
            for (key, group), out in zip(jobs, outs):
                if isinstance(out, BaseException):
                    error = error or out
                else:
                    self._record_group(key, group, out)
            if error is not None:
                raise error
        else:
            for key in keys:
                self._flush_group(key)
        return self.poll()

    def run_fleet(self, graphs: Iterable[CSRGraph], ops=None) -> List[Any]:
        """Submit a whole fleet (one ``ops`` set for all), flush, and
        return results in input order.

        Completions belonging to requests submitted *before* this call
        (drained by the flush) are retained for the next :meth:`poll` —
        never discarded.
        """
        ids = [self.submit(g, ops) for g in graphs]
        mine = set(ids)
        done = {}
        others = []
        for c in self.flush():
            if c.request_id in mine:
                done[c.request_id] = c.result
            else:
                others.append(c)
        self._completed.extend(others)
        return [done[i] for i in ids]

    @property
    def pending(self) -> int:
        """Number of submitted-but-not-yet-executed requests."""
        return sum(len(g) for g in self._pending.values())

    # -- execution -----------------------------------------------------------

    def _flush_group(self, key) -> None:
        meta, ops_t = key
        group = self._pending.pop(key)
        self._first_seq.pop(key)
        plan = compile(meta, ops_t, self.config.census, mesh=self.mesh)
        self._record_group(key, group, self._execute_group(plan, group))

    def _execute_group(self, plan, group) -> dict:
        """Run one group's batch; returns results + the plan-stat deltas.

        Thread-safe against other groups: distinct (bucket, ops) keys
        map to distinct plans, so concurrent group threads touch
        disjoint plan state (service bookkeeping stays on the caller's
        thread — see :meth:`_record_group`)."""
        before = {k: plan.stats[k] for k in ("host_syncs", "chunks")}
        before_dev = dict(plan.stats["device_chunks"])
        results = plan.run_batch([g for _, g in group])
        dev = {d: c - before_dev.get(d, 0)
               for d, c in plan.stats["device_chunks"].items()
               if c - before_dev.get(d, 0)}
        return dict(results=results,
                    host_syncs=plan.stats["host_syncs"] - before["host_syncs"],
                    chunks=plan.stats["chunks"] - before["chunks"],
                    device_chunks=dev)

    def _record_group(self, key, group, out: dict) -> None:
        meta, ops_t = key
        results = out["results"]
        if len(ops_t) == 1:  # single-op requests complete with bare results
            results = [r[ops_t[0]] for r in results]
        st = self._bucket_stats[meta]
        st["batches"] += 1
        st["batched_graphs"] += len(group)
        st["host_syncs"] += out["host_syncs"]
        st["chunks"] += out["chunks"]
        for d, c in out["device_chunks"].items():
            self._device_chunks[d] = self._device_chunks.get(d, 0) + c
        self._completed.extend(
            CensusCompletion(rid, res, meta, ops_t)
            for (rid, _), res in zip(group, results))

    # -- introspection -------------------------------------------------------

    def stats(self) -> dict:
        """Service-level + per-bucket serving statistics.

        ``buckets`` maps each :class:`GraphMeta` to its request/batch
        counts, ``occupancy`` (batched graphs per flushed batch slot —
        1.0 means every batch left full), the host syncs / chunks its
        batches cost, and ``by_ops`` (requests per ops tuple — the
        mixed-analytic split).  ``mean_batch`` is the fleet-wide average
        batch width — the dispatch amortization factor actually achieved.
        ``devices`` maps executor pool device index → chunks the service
        dispatched there across all batches (all on device 0 under the
        default static schedule; spread across the pool under
        ``CensusConfig(schedule="dynamic")`` — whether the fleet actually
        fans out over the hardware, measured).  ``sessions`` maps each
        live subscribed-session id to its mutation counters —
        ``mutations`` split into ``deltas`` (affected-subset path),
        ``fulls`` (cost-model fallback) and ``recompiles`` (bucket
        outgrowth) — plus the session's current graph size and ops; the
        delta/full split is the incremental engine's hit rate, the number
        that says whether the mutation stream is actually local.
        """
        buckets = {}
        total_batches = total_graphs = 0
        for meta, st in self._bucket_stats.items():
            occ = (st["batched_graphs"]
                   / (st["batches"] * self.config.max_batch)
                   if st["batches"] else 0.0)
            buckets[meta] = {**st, "by_ops": dict(st["by_ops"]),
                             "occupancy": occ}
            total_batches += st["batches"]
            total_graphs += st["batched_graphs"]
        return dict(
            requests=self._seq,
            pending=self.pending,
            batches=total_batches,
            mean_batch=(total_graphs / total_batches
                        if total_batches else 0.0),
            buckets=buckets,
            devices=dict(self._device_chunks),
            sessions={sid: dict(mutations=s.mutations, deltas=s.deltas,
                                fulls=s.fulls, recompiles=s.recompiles,
                                n=s.graph.n, m=s.graph.m, ops=s.ops)
                      for sid, s in self._sessions.items()},
        )
