"""Batched multi-graph, multi-analytic serving (the fleet front door).

The engine's plan cache already amortizes *compilation* across same-shape
graphs; this layer amortizes *dispatch*.  A :class:`CensusService` accepts
a stream of :class:`~repro.core.graph.CSRGraph` requests — each optionally
naming the :class:`~repro.engine.GraphOp` analytics it wants — groups them
by (:class:`~repro.engine.GraphMeta` bucket, ops) key, and executes each
group as ONE vmapped fixed-shape batch through ``Plan.run_batch``: B
requests for one chunk schedule of dispatches and one device→host
transfer, every requested analytic computed in the same fused pass.  That
is the workload shape of triadic analysis over graph *collections* (Chin
et al., "Scalable Triadic Analysis of Large-Scale Graphs"): many small
same-shape graphs and a family of analyses, not one giant kernel launch.

Design properties:

  * **Deterministic, clockless batching** — groups flush when they reach
    ``max_batch`` or when ``max_wait_requests`` newer requests have been
    submitted since the group's oldest member (bounded staleness without
    wall-clock timers, so behavior is exactly reproducible in tests).
  * **Out-of-order completion, stable ids** — ``submit`` returns a
    monotonically increasing request id; completions surface in batch
    flush order, each tagged with its id, bucket, and ops.
  * **Per-bucket stats** — batches formed, occupancy, host syncs, and a
    per-ops request breakdown: the numbers that tell you whether the
    fleet is actually batching.

Synchronous by construction: batches execute inside ``submit``/``flush``
on the caller's thread (device work itself is still async under the
engine's double-buffered dispatcher).  One exception: when the engine
config selects the dynamic executor schedule
(``CensusConfig(schedule="dynamic")``), :meth:`CensusService.flush`
drains multi-group backlogs through the executor device pool
*concurrently* — each (bucket, ops) group runs on its own thread, its
chunks work-queued over the shared pool, so different buckets occupy
different devices at the same time.  Per-device chunk occupancy is
surfaced in :meth:`CensusService.stats`.

Beyond the stateless request stream, the service also runs **subscribed
sessions** — the evolving-graph mode (Chin et al.'s workload is edge
traffic, not whole-graph resubmission): :meth:`CensusService.subscribe`
pins a graph and its ops, clients stream
:meth:`~CensusService.mutate`\\ (session,
:class:`~repro.core.delta.GraphDelta`) and read fresh counts with
:meth:`~CensusService.poll`\\ (session) at any time.  Each mutation rides
``Plan.apply_delta`` — work proportional to the mutation footprint, one
device→host sync — falling back to a full recompute past the
``delta_threshold`` cost model, and transparently recompiling (plan
cache — other sessions in the same bucket share it) when a mutation
outgrows the session plan's metadata buckets.  Per-session delta / full
/ recompile counters surface in :meth:`CensusService.stats`.
"""
from __future__ import annotations

import dataclasses
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, Iterable, List, NamedTuple, Optional, Tuple

from ..core.delta import GraphDelta, apply_delta_csr
from ..core.graph import CSRGraph
from ..engine import CensusConfig, GraphMeta, PlanShapeError, compile
from ..engine.ops import get_op, resolve_ops

__all__ = ["AdmissionError", "CensusCompletion", "CensusService",
           "DeadlineExceeded", "ServiceConfig"]

_DEFAULT_OPS = ("triad_census",)

REJECT_POLICIES = ("reject", "flush_oldest")


class AdmissionError(RuntimeError):
    """Backpressure signal: the service's pending queue is at
    ``ServiceConfig.max_pending`` and ``reject_policy="reject"`` refused
    a new request.  Typed so load-shedding callers can catch admission
    rejections apart from execution failures; the rejected request was
    never assigned an id and holds no service state."""


class DeadlineExceeded(RuntimeError):
    """A request's ``deadline_rounds`` budget ran out before its group
    executed: the request completes with this as its
    ``CensusCompletion.error`` payload instead of result data.
    Deadlines are measured in *flush rounds* (group executions), never
    wall clocks, so expiry is exactly reproducible in tests."""


def _normalize_ops(ops) -> Tuple[str, ...]:
    """Per-request ops spec -> validated tuple of registered op names.

    Validation happens here, at submit time, so a bad spec (typo'd name,
    unregistered instance) rejects the one request instead of surfacing
    at flush time and taking its whole batch group down with it.  Groups
    are keyed (and flushed) by *name*, so a GraphOp instance is accepted
    only if it IS the registered op of that name — a name-colliding
    unregistered instance must not be silently swapped for the
    registry's implementation."""
    if ops is None:
        return _DEFAULT_OPS
    names = []
    for op in resolve_ops(ops):
        if get_op(op.name) is not op:  # KeyError if the name is unknown
            raise ValueError(
                f"service requests resolve ops by name at flush time, but "
                f"the submitted {op.name!r} instance is not the registered "
                f"one — register_op(...) it (overwrite=True to replace the "
                f"existing registration) before submitting")
        names.append(op.name)
    return tuple(names)


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Batching policy for a :class:`CensusService`.

    Attributes:
        max_batch: flush a group as soon as it holds this many requests —
            the vmapped batch width the service aims for.  Larger batches
            amortize dispatch further but retrace the batched unit once
            per new (power-of-two-padded) width.
        max_wait_requests: bounded-staleness valve.  A partial group is
            force-flushed once this many *other-group* requests have
            been submitted since the group's oldest member — a rare
            bucket can never wait forever behind hot ones, while a hot
            bucket's own burst is still allowed to fill to
            ``max_batch``.  ``0`` disables waiting entirely: every
            submit flushes immediately (B = 1, the unbatched baseline).
            Counted in requests, not seconds, so tests are
            deterministic.
        census: the :class:`~repro.engine.EngineConfig` every request
            executes under — together with the request's (bucket, ops)
            key it pins the plan-cache entry, so one service maps to at
            most one cached plan per (bucket, ops) group.
        max_sessions: cap on concurrently subscribed evolving-graph
            sessions (:meth:`CensusService.subscribe`).  Each live
            session pins its current graph, raw accumulator bins and a
            plan-cache reference, so the cap bounds the service's
            resident state; ``subscribe`` past it raises until a session
            is :meth:`~CensusService.unsubscribe`\\ d.
        max_pending: admission-control cap on submitted-but-not-executed
            requests (``None`` = unbounded, the pre-hardening behavior).
            A submit that would exceed it triggers ``reject_policy``.
            Every pending request pins its graph in host memory, so this
            is the service's backpressure valve.
        max_attempts: execution attempts per *request* when its batch
            fails: after a failed ``run_batch`` the group retries
            member-wise, each member up to ``max_attempts`` times, so
            one poison graph surfaces as a single failed
            :class:`CensusCompletion` (with ``error`` payload) instead
            of taking down its batch peers.  Independent of the
            engine-level per-chunk ``EngineConfig.max_attempts``.
        reject_policy: what a full pending queue does to a new submit —
            ``"reject"`` raises :class:`AdmissionError` (shed load onto
            the caller), ``"flush_oldest"`` synchronously flushes the
            group holding the oldest pending request to free capacity,
            then admits.
    """

    max_batch: int = 8
    max_wait_requests: int = 64
    census: CensusConfig = dataclasses.field(default_factory=CensusConfig)
    max_sessions: int = 64
    max_pending: Optional[int] = None
    max_attempts: int = 2
    reject_policy: str = "reject"

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.max_wait_requests < 0:
            raise ValueError("max_wait_requests must be >= 0")
        if self.max_sessions < 1:
            raise ValueError("max_sessions must be >= 1")
        if self.max_pending is not None and self.max_pending < 1:
            raise ValueError(
                f"max_pending must be >= 1 (got {self.max_pending}); use "
                "None for an unbounded pending queue")
        if self.max_attempts < 1:
            raise ValueError(
                f"max_attempts must be >= 1 (got {self.max_attempts}); it "
                "is the per-request execution budget after a batch failure")
        if self.reject_policy not in REJECT_POLICIES:
            raise ValueError(
                f"reject_policy must be one of {REJECT_POLICIES}, got "
                f"{self.reject_policy!r}")


class CensusCompletion(NamedTuple):
    """One finished request: the id ``submit`` returned, its result, the
    metadata bucket it was batched under, and the ops it ran.  For a
    single-op request (the default census-only case) ``result`` is that
    op's bare result object — a ``CensusResult`` for ``triad_census`` —
    and for a multi-op request it is the fused ``{op_name: result}``
    dict.  A request that *failed* (poison graph, exhausted retries, a
    missed deadline, a dead group thread) still completes — with
    ``result=None`` and the failure as its ``error`` payload — so one
    bad request never silently drops, and never takes its batch peers'
    results down with it."""

    request_id: int
    result: Any
    meta: GraphMeta
    ops: Tuple[str, ...] = _DEFAULT_OPS
    error: Optional[BaseException] = None


class _Request(NamedTuple):
    """One pending entry: stable id, the graph, and the flush-round
    number after which the request expires (None = no deadline)."""

    rid: int
    graph: CSRGraph
    expiry: Optional[int] = None


@dataclasses.dataclass
class _Session:
    """One subscribed evolving graph: its current state + plan + counters."""

    graph: CSRGraph
    ops: Tuple[str, ...]
    plan: Any
    raw: Any  # (total_bins,) int64 — the plan's raw fused accumulator
    mutations: int = 0
    deltas: int = 0      # mutations served by the affected-subset path
    fulls: int = 0       # mutations that fell back to a full recompute
    recompiles: int = 0  # mutations that outgrew the plan's buckets
    failed: int = 0      # mutations rolled back after a mid-mutate failure


class CensusService:
    """Plan-cache-aware batched serving over a mixed-analytic request
    stream.

    ::

        svc = CensusService(ServiceConfig(max_batch=8,
                                          census=CensusConfig(backend="xla")))
        rid = svc.submit(graph)                        # census request
        rid2 = svc.submit(graph, ops=("triad_census",
                                      "degree_stats")) # fused multi-op
        done = svc.flush()             # force-run all partial groups
        for c in done:                 # CensusCompletion, flush order
            ...

    Requests are grouped by (graph bucket, ops): a census-only fleet and
    a multi-analytic fleet over the same graphs batch separately (they
    run different fused plans), but everything inside a group rides one
    vmapped pass.  ``mesh`` is forwarded to the engine for the
    distributed backend; leave ``None`` for the default single-host mesh.
    """

    def __init__(self, config: Optional[ServiceConfig] = None, *, mesh=None):
        self.config = config or ServiceConfig()
        self.mesh = mesh
        # (meta, ops) -> [(rid, graph)] / oldest rid
        self._pending: Dict[tuple, list] = {}
        self._first_seq: Dict[tuple, int] = {}
        self._completed: List[CensusCompletion] = []
        self._seq = 0
        self._bucket_stats: Dict[GraphMeta, dict] = {}
        self._device_chunks: Dict[int, int] = {}
        self._sessions: Dict[int, _Session] = {}
        self._session_seq = 0
        # flush-round clock (one tick per executed/failed group) — the
        # clockless time base request deadlines are measured against.
        self._rounds = 0
        self._health = dict(retries=0, quarantines=0, backend_fallbacks=0,
                            schedule_fallbacks=0, rejections=0, poisoned=0,
                            expired=0, batch_failures=0, group_failures=0,
                            mutate_failures=0)

    # -- request path --------------------------------------------------------

    def _admit(self) -> None:
        """Admission control: enforce ``max_pending`` per the configured
        ``reject_policy`` before a new request takes a queue slot."""
        cap = self.config.max_pending
        if cap is None:
            return
        while self.pending >= cap:
            if self.config.reject_policy == "reject":
                self._health["rejections"] += 1
                raise AdmissionError(
                    f"pending queue full ({self.pending} >= max_pending="
                    f"{cap}); flush(), poll later, or configure "
                    f"reject_policy='flush_oldest'")
            # flush_oldest: free capacity by executing the group holding
            # the oldest pending request, then admit.
            oldest = min(self._first_seq, key=self._first_seq.get)
            self._flush_group(oldest)

    def submit(self, graph: CSRGraph, ops=None, *,
               deadline_rounds: Optional[int] = None) -> int:
        """Queue one analytic request; returns its stable request id.

        ``ops`` names the :class:`~repro.engine.GraphOp` set to run — a
        name, a sequence of names, or ``None`` for the census-only
        default.  If the request fills its (bucket, ops) group to
        ``max_batch``, the group executes immediately (synchronously);
        any group gone stale under ``max_wait_requests`` is flushed too.
        Completions are held until :meth:`poll`.

        ``deadline_rounds`` bounds how long the request may sit pending,
        measured in flush rounds (group executions — the service's
        clockless time base): a request still pending after that many
        further rounds completes with a :class:`DeadlineExceeded` error
        payload instead of executing.  ``None`` = no deadline.  A full
        pending queue (``max_pending``) applies ``reject_policy`` first —
        ``"reject"`` raises :class:`AdmissionError` before an id is
        assigned.
        """
        if deadline_rounds is not None and deadline_rounds < 0:
            raise ValueError(
                f"deadline_rounds must be >= 0 (got {deadline_rounds}); "
                "use None for no deadline")
        self._expire_overdue()
        self._admit()
        rid = self._seq
        self._seq += 1
        ops_t = _normalize_ops(ops)
        meta = GraphMeta.from_graph(graph, k=self.config.census.k)
        key = (meta, ops_t)
        group = self._pending.setdefault(key, [])
        if not group:
            self._first_seq[key] = rid
        expiry = (None if deadline_rounds is None
                  else self._rounds + deadline_rounds)
        group.append(_Request(rid, graph, expiry))
        st = self._bucket_stats.setdefault(
            meta, dict(requests=0, batches=0, batched_graphs=0,
                       host_syncs=0, chunks=0, by_ops={}))
        st["requests"] += 1
        st["by_ops"][ops_t] = st["by_ops"].get(ops_t, 0) + 1
        if len(group) >= self.config.max_batch:
            self._flush_group(key)
        # staleness: count only OTHER groups' arrivals since a group's
        # oldest member — a hot group's own burst must still be allowed
        # to fill to max_batch.
        for stale in [k for k, s in self._first_seq.items()
                      if (self._seq - s - len(self._pending[k])
                          >= self.config.max_wait_requests)]:
            self._flush_group(stale)
        return rid

    def _expire_overdue(self) -> None:
        """Complete (with :class:`DeadlineExceeded` payloads) every
        pending request whose flush-round deadline has passed.  Runs at
        every submit and flush entry, so an expired request is surfaced
        by the next service interaction — never left pending."""
        for key in list(self._pending):
            group = self._pending[key]
            dead = [r for r in group
                    if r.expiry is not None and self._rounds > r.expiry]
            if not dead:
                continue
            keep = [r for r in group if r not in dead]
            meta, ops_t = key
            self._health["expired"] += len(dead)
            self._completed.extend(
                CensusCompletion(r.rid, None, meta, ops_t,
                                 error=DeadlineExceeded(
                                     f"request {r.rid} expired after "
                                     f"deadline round {r.expiry} (now round "
                                     f"{self._rounds})"))
                for r in dead)
            if keep:
                self._pending[key] = keep
            else:
                del self._pending[key]
                del self._first_seq[key]

    def poll(self, session: Optional[int] = None):
        """Without arguments: drain and return completions accumulated
        since the last poll (order is batch flush order — generally NOT
        submission order; match on ``request_id``).

        With a ``session`` id (from :meth:`subscribe`): the subscribed
        graph's fresh analytics — finalized from the session's cached raw
        accumulator bins, so polling costs host-side closed forms only,
        no device work.  Single-op sessions return the bare result object
        (a ``CensusResult`` for the census default), multi-op sessions
        the ``{op_name: result}`` dict — same unwrapping as request
        completions."""
        if session is not None:
            return self._session_results(self._session(session))
        out, self._completed = self._completed, []
        return out

    # -- subscribed evolving-graph sessions ----------------------------------

    def _session(self, session: int) -> _Session:
        try:
            return self._sessions[session]
        except KeyError:
            raise KeyError(f"unknown session {session!r}; live sessions: "
                           f"{sorted(self._sessions)}") from None

    def _session_results(self, s: _Session):
        results = s.plan.layout.finalize(s.raw, s.graph)
        return results[s.ops[0]] if len(s.ops) == 1 else results

    def subscribe(self, graph: CSRGraph, ops=None) -> int:
        """Pin an evolving graph; returns its session id.

        The session compiles (or reuses from the plan cache) the fused
        plan for ``(graph bucket, ops)``, runs one full pass to seed the
        raw accumulator state, and is then ready to take
        :meth:`mutate` streams; :meth:`poll`\\ (session) reads fresh
        counts at any time.  ``ops`` follows :meth:`submit`'s convention
        (``None`` = census only).  Raises once
        ``ServiceConfig.max_sessions`` sessions are live."""
        ops_t = _normalize_ops(ops)
        if len(self._sessions) >= self.config.max_sessions:
            raise RuntimeError(
                f"session limit reached (max_sessions="
                f"{self.config.max_sessions}); unsubscribe() a session "
                "before subscribing another graph")
        plan = compile(graph, ops_t, self.config.census, mesh=self.mesh)
        sid = self._session_seq
        self._session_seq += 1
        self._sessions[sid] = _Session(graph=graph, ops=ops_t, plan=plan,
                                       raw=plan.run_raw(graph))
        return sid

    def mutate(self, session: int, delta: GraphDelta) -> dict:
        """Apply one mutation batch to a subscribed graph.

        Rides ``Plan.apply_delta``: the affected-subset correction (work
        proportional to the delta's footprint, ONE device→host sync) when
        the mutation is local enough, the plan's full pass otherwise
        (``delta_threshold`` cost model) — results are bit-identical
        either way.  A mutation that outgrows the session plan's metadata
        buckets (degree or arc-count growth past the bucketized shape)
        transparently recompiles through the plan cache at the new shape
        and reseeds with one full pass.  Returns an ack dict: ``mode``
        (``"delta"`` | ``"full"`` | ``"recompile"``),
        ``affected_fraction``, and the new ``n`` / ``m``; read the fresh
        counts with :meth:`poll`\\ (session).

        **Failure atomicity**: a mutation that fails mid-way (an
        injected or real execution failure at any point — delta pass,
        full recompute, or recompile reseed) re-raises AND rolls the
        session back to its pre-mutation (graph, raw bins, plan)
        snapshot, so a subscribed session never serves corrupted counts
        — :meth:`poll`\\ (session) keeps answering from the last good
        state.  Rolled-back mutations are counted per session
        (``failed``) and in ``stats()["health"]["mutate_failures"]``."""
        s = self._session(session)
        snapshot = (s.graph, s.raw, s.plan)
        try:
            try:
                out = s.plan.apply_delta(s.graph, delta, s.raw)
                s.graph, s.raw = out.graph, out.raw
                mode, frac = out.mode, out.affected_fraction
                if mode == "delta":
                    s.deltas += 1
                else:
                    s.fulls += 1
            except PlanShapeError:
                # compute the whole new state BEFORE committing any of it:
                # a failure inside the recompile reseed must leave the
                # session on its old (graph, raw, plan) triple.
                g_new = apply_delta_csr(s.graph, delta)
                plan_new = compile(g_new, s.ops, self.config.census,
                                   mesh=self.mesh)
                raw_new = plan_new.run_raw(g_new)
                s.plan, s.graph, s.raw = plan_new, g_new, raw_new
                s.recompiles += 1
                mode, frac = "recompile", 1.0
        except Exception:
            s.graph, s.raw, s.plan = snapshot
            s.failed += 1
            self._health["mutate_failures"] += 1
            raise
        s.mutations += 1
        return dict(session=session, mode=mode, affected_fraction=frac,
                    n=s.graph.n, m=s.graph.m)

    def unsubscribe(self, session: int):
        """End a session, freeing its ``max_sessions`` slot; returns the
        final analytics (same shape :meth:`poll`\\ (session) returns)."""
        s = self._session(session)
        del self._sessions[session]
        return self._session_results(s)

    def flush(self) -> List[CensusCompletion]:
        """Execute every pending partial group, then drain completions.

        Under the engine's dynamic executor schedule a multi-group
        backlog drains **concurrently**: every group's plan is compiled
        up front (the plan cache is touched only from this thread), then
        each group executes on its own thread, its chunks work-queued
        over the shared executor device pool — different buckets land on
        different devices at the same time.  Results and completion
        order are identical to the sequential drain (integer arithmetic;
        groups are recorded in submission order).

        **Consistency under failure**: a group whose thread dies
        mid-flush fails its requests *explicitly* — each surfaces as a
        :class:`CensusCompletion` with the error payload — and the queue
        / session tables stay consistent (``pending`` is 0 after any
        flush; nothing is ever stuck or silently dropped), while peer
        groups' results are recorded normally.  Per-request failures
        inside a live group (poison graphs) are isolated member-wise by
        :meth:`_execute_group` before they can reach here."""
        self._expire_overdue()
        keys = list(self._pending)
        if len(keys) > 1 and self.config.census.schedule == "dynamic":
            # compile every plan BEFORE popping any group (the plan cache
            # is touched only from this thread, and a compile failure
            # must leave every request pending, not dropped).
            plans = {key: compile(key[0], key[1], self.config.census,
                                  mesh=self.mesh) for key in keys}
            jobs = []
            for key in keys:
                group = self._pending.pop(key)
                self._first_seq.pop(key)
                jobs.append((key, group))
            # cap group concurrency at the executor pool width: more
            # flush threads than devices only oversubscribes the pool
            # (each group's executor spawns its own per-device workers)
            # and multiplies peak device memory by the group count.
            width = max(p.executor.n_devices for p in plans.values())
            with ThreadPoolExecutor(
                    max_workers=min(len(jobs), max(width, 1))) as pool:
                futs = [pool.submit(self._execute_group, plans[key], group)
                        for key, group in jobs]
                outs = [f.result() if not f.exception() else f.exception()
                        for f in futs]
            # every group is recorded — results for the live ones,
            # explicit per-request error completions for a dead one — so
            # a bad group can neither discard its peers' results nor
            # leave its own requests pending forever.
            for (key, group), out in zip(jobs, outs):
                self._record_outcome(key, group, out)
        else:
            for key in keys:
                self._flush_group(key)
        return self.poll()

    def run_fleet(self, graphs: Iterable[CSRGraph], ops=None) -> List[Any]:
        """Submit a whole fleet (one ``ops`` set for all), flush, and
        return results in input order.

        Completions belonging to requests submitted *before* this call
        (drained by the flush) are retained for the next :meth:`poll` —
        never discarded.  A fleet member that *failed* (poison graph,
        exhausted retries) yields ``None`` in its slot — check the
        completion stream via :meth:`submit` + :meth:`flush` directly
        when per-request error payloads matter.
        """
        ids = [self.submit(g, ops) for g in graphs]
        mine = set(ids)
        done = {}
        others = []
        for c in self.flush():
            if c.request_id in mine:
                done[c.request_id] = c.result
            else:
                others.append(c)
        self._completed.extend(others)
        return [done[i] for i in ids]

    @property
    def pending(self) -> int:
        """Number of submitted-but-not-yet-executed requests."""
        return sum(len(g) for g in self._pending.values())

    # -- execution -----------------------------------------------------------

    def _flush_group(self, key) -> None:
        meta, ops_t = key
        group = self._pending.pop(key)
        self._first_seq.pop(key)
        plan = compile(meta, ops_t, self.config.census, mesh=self.mesh)
        try:
            out = self._execute_group(plan, group)
        except BaseException as e:  # same contract as the dynamic drain:
            # the group's requests fail explicitly, never silently drop.
            self._record_outcome(key, group, e)
            raise
        self._record_outcome(key, group, out)

    def _execute_group(self, plan, group) -> dict:
        """Run one group's batch; returns results + the plan-stat deltas.

        **Member-wise isolation**: if the batch fails as a unit (one
        poison graph poisons the whole vmapped pass), every member
        retries individually — up to ``ServiceConfig.max_attempts``
        each — so healthy peers still produce results and only the bad
        request carries an error payload.  No exception escapes for
        per-member failures.

        Thread-safe against other groups: distinct (bucket, ops) keys
        map to distinct plans, so concurrent group threads touch
        disjoint plan state (service bookkeeping stays on the caller's
        thread — see :meth:`_record_outcome`)."""
        before = {k: plan.stats[k] for k in ("host_syncs", "chunks")}
        before_dev = dict(plan.stats["device_chunks"])
        before_faults = dict(plan.stats["faults"])
        graphs = [r.graph for r in group]
        errors: list = [None] * len(group)
        batch_failed = 0
        try:
            results = plan.run_batch(graphs)
        except Exception:
            # the batch is poisoned as a unit — retry member-wise so one
            # bad graph costs one failed completion, not the group.
            batch_failed = 1
            results = [None] * len(group)
            for i, g in enumerate(graphs):
                for _ in range(self.config.max_attempts):
                    try:
                        results[i] = plan.run(g)
                        errors[i] = None
                        break
                    except Exception as e:
                        errors[i] = e
        dev = {d: c - before_dev.get(d, 0)
               for d, c in plan.stats["device_chunks"].items()
               if c - before_dev.get(d, 0)}
        faults = {k: v - before_faults.get(k, 0)
                  for k, v in plan.stats["faults"].items()}
        part = plan.stats.get("partition")
        return dict(results=results, errors=errors, batch_failed=batch_failed,
                    faults=faults,
                    host_syncs=plan.stats["host_syncs"] - before["host_syncs"],
                    chunks=plan.stats["chunks"] - before["chunks"],
                    device_chunks=dev,
                    partitions=plan.partitions,
                    partition=dict(part) if part else None)

    def _record_outcome(self, key, group, out) -> None:
        """Fold one executed (or dead) group into service state — always
        on the flush caller's thread, so bucket stats, health counters
        and the completion list need no locks.  ``out`` is
        :meth:`_execute_group`'s dict for a live group, or the exception
        that killed its thread — in which case every request completes
        explicitly with that error as payload (the queue was already
        popped; nothing stays pending)."""
        meta, ops_t = key
        self._rounds += 1
        if isinstance(out, BaseException):
            self._health["group_failures"] += 1
            self._completed.extend(
                CensusCompletion(r.rid, None, meta, ops_t, error=out)
                for r in group)
            return
        results = out["results"]
        errors = out["errors"]
        if len(ops_t) == 1:  # single-op requests complete with bare results
            results = [r if r is None else r[ops_t[0]] for r in results]
        st = self._bucket_stats[meta]
        st["batches"] += 1
        st["batched_graphs"] += len(group)
        st["host_syncs"] += out["host_syncs"]
        st["chunks"] += out["chunks"]
        for d, c in out["device_chunks"].items():
            self._device_chunks[d] = self._device_chunks.get(d, 0) + c
        if out.get("partition"):
            # last partitioned layout this bucket executed (cuts, halo
            # sizes, per-shard dyads, spill footprint) — see
            # repro.engine.partition.run_partitioned.
            st["partitions"] = out["partitions"]
            st["partition"] = out["partition"]
        self._health["batch_failures"] += out["batch_failed"]
        self._health["poisoned"] += sum(1 for e in errors if e is not None)
        for k in ("retries", "quarantines", "backend_fallbacks",
                  "schedule_fallbacks"):
            self._health[k] += out["faults"].get(k, 0)
        self._completed.extend(
            CensusCompletion(r.rid, res, meta, ops_t, error=err)
            for r, res, err in zip(group, results, errors))

    # -- introspection -------------------------------------------------------

    def stats(self) -> dict:
        """Service-level + per-bucket serving statistics.

        ``buckets`` maps each :class:`GraphMeta` to its request/batch
        counts, ``occupancy`` (batched graphs per flushed batch slot —
        1.0 means every batch left full), the host syncs / chunks its
        batches cost, and ``by_ops`` (requests per ops tuple — the
        mixed-analytic split); buckets serving a partitioned plan
        (``CensusConfig(partitions > 1)``) additionally report
        ``partitions`` and ``partition`` — the last executed shard
        layout and its concurrency: cuts, per-shard dyad counts, halo
        sizes, the spill staging footprint, plus the residency
        observables (``mode``, ``h2d_puts`` / ``d2d_puts`` transfer
        counts, ``max_shard_bytes``, per-shard ``shard_times`` and the
        ``shard_overlap`` concurrency fraction — see
        :mod:`repro.engine.partition`).  ``mean_batch`` is the fleet-wide average
        batch width — the dispatch amortization factor actually achieved.
        ``devices`` maps executor pool device index → chunks the service
        dispatched there across all batches (all on device 0 under the
        default static schedule; spread across the pool under
        ``CensusConfig(schedule="dynamic")`` — whether the fleet actually
        fans out over the hardware, measured).  ``sessions`` maps each
        live subscribed-session id to its mutation counters —
        ``mutations`` split into ``deltas`` (affected-subset path),
        ``fulls`` (cost-model fallback) and ``recompiles`` (bucket
        outgrowth), plus ``failed`` (mutations rolled back to the
        pre-mutation snapshot) — plus the session's current graph size
        and ops; the delta/full split is the incremental engine's hit
        rate, the number that says whether the mutation stream is
        actually local.  ``rounds`` is the flush-round clock deadlines
        are measured against, and ``health`` aggregates every recovery
        the service has performed: engine-level ``retries`` /
        ``quarantines`` / ``backend_fallbacks`` / ``schedule_fallbacks``
        (summed from the plans' fault counters), plus service-level
        ``rejections`` (admission control), ``expired`` (missed
        deadlines), ``batch_failures`` (groups that retried
        member-wise), ``poisoned`` (requests completing with error
        payloads), ``group_failures`` (dead flush threads) and
        ``mutate_failures`` (rolled-back session mutations) — all zeros
        on a healthy service.
        """
        buckets = {}
        total_batches = total_graphs = 0
        for meta, st in self._bucket_stats.items():
            occ = (st["batched_graphs"]
                   / (st["batches"] * self.config.max_batch)
                   if st["batches"] else 0.0)
            buckets[meta] = {**st, "by_ops": dict(st["by_ops"]),
                             "occupancy": occ}
            total_batches += st["batches"]
            total_graphs += st["batched_graphs"]
        return dict(
            requests=self._seq,
            pending=self.pending,
            batches=total_batches,
            mean_batch=(total_graphs / total_batches
                        if total_batches else 0.0),
            buckets=buckets,
            devices=dict(self._device_chunks),
            rounds=self._rounds,
            health=dict(self._health),
            sessions={sid: dict(mutations=s.mutations, deltas=s.deltas,
                                fulls=s.fulls, recompiles=s.recompiles,
                                failed=s.failed,
                                n=s.graph.n, m=s.graph.m, ops=s.ops)
                      for sid, s in self._sessions.items()},
        )
