"""Batched multi-graph census serving (the fleet front door).

The engine's plan cache already amortizes *compilation* across same-shape
graphs; this layer amortizes *dispatch*.  A :class:`CensusService` accepts
a stream of :class:`~repro.core.graph.CSRGraph` requests, groups them by
their :class:`~repro.engine.GraphMeta` bucket key (the plan-cache key's
graph half), and executes each same-bucket group as ONE vmapped
fixed-shape batch through ``CensusPlan.run_batch`` — B small censuses for
one chunk schedule of dispatches and one device→host transfer.  That is
the workload shape of triadic analysis over graph *collections* (Chin et
al., "Scalable Triadic Analysis of Large-Scale Graphs"): many small
same-shape graphs, not one giant kernel launch.

Design properties:

  * **Deterministic, clockless batching** — groups flush when they reach
    ``max_batch`` or when ``max_wait_requests`` newer requests have been
    submitted since the group's oldest member (bounded staleness without
    wall-clock timers, so behavior is exactly reproducible in tests).
  * **Out-of-order completion, stable ids** — ``submit`` returns a
    monotonically increasing request id; completions surface in batch
    flush order, each tagged with its id and bucket.
  * **Per-bucket stats** — batches formed, occupancy, host syncs: the
    numbers that tell you whether the fleet is actually batching.

Synchronous by construction: batches execute inside ``submit``/``flush``
on the caller's thread (device work itself is still async under the
engine's double-buffered dispatcher).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, NamedTuple, Optional

from ..core.census import CensusResult
from ..core.graph import CSRGraph
from ..engine import CensusConfig, GraphMeta, compile_census

__all__ = ["CensusCompletion", "CensusService", "ServiceConfig"]


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Batching policy for a :class:`CensusService`.

    Attributes:
        max_batch: flush a bucket group as soon as it holds this many
            requests — the vmapped batch width the service aims for.
            Larger batches amortize dispatch further but retrace the
            batched unit once per new (power-of-two-padded) width.
        max_wait_requests: bounded-staleness valve.  A partial group is
            force-flushed once this many *other-bucket* requests have
            been submitted since the group's oldest member — a rare
            bucket can never wait forever behind hot ones, while a hot
            bucket's own burst is still allowed to fill to
            ``max_batch``.  ``0`` disables waiting entirely: every
            submit flushes immediately (B = 1, the unbatched baseline).
            Counted in requests, not seconds, so tests are
            deterministic.
        census: the :class:`~repro.engine.CensusConfig` every request
            executes under — the other half of the plan-cache key, so one
            service maps to at most one cached plan per bucket.
    """

    max_batch: int = 8
    max_wait_requests: int = 64
    census: CensusConfig = dataclasses.field(default_factory=CensusConfig)

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.max_wait_requests < 0:
            raise ValueError("max_wait_requests must be >= 0")


class CensusCompletion(NamedTuple):
    """One finished request: the id ``submit`` returned, its result, and
    the metadata bucket it was batched under."""

    request_id: int
    result: CensusResult
    meta: GraphMeta


class CensusService:
    """Plan-cache-aware batched census serving over a request stream.

    ::

        svc = CensusService(ServiceConfig(max_batch=8,
                                          census=CensusConfig(backend="xla")))
        rid = svc.submit(graph)        # queues; may flush a full batch
        done = svc.flush()             # force-run all partial groups
        for c in done:                 # CensusCompletion, flush order
            ...

    ``mesh`` is forwarded to ``compile_census`` for the distributed
    backend; leave ``None`` for the default single-host mesh.
    """

    def __init__(self, config: Optional[ServiceConfig] = None, *, mesh=None):
        self.config = config or ServiceConfig()
        self.mesh = mesh
        self._pending: Dict[GraphMeta, list] = {}   # meta -> [(rid, graph)]
        self._first_seq: Dict[GraphMeta, int] = {}  # meta -> oldest rid
        self._completed: List[CensusCompletion] = []
        self._seq = 0
        self._bucket_stats: Dict[GraphMeta, dict] = {}

    # -- request path --------------------------------------------------------

    def submit(self, graph: CSRGraph) -> int:
        """Queue one census request; returns its stable request id.

        If the request fills its bucket group to ``max_batch``, the group
        executes immediately (synchronously); any group gone stale under
        ``max_wait_requests`` is flushed too.  Completions are held until
        :meth:`poll`.
        """
        rid = self._seq
        self._seq += 1
        meta = GraphMeta.from_graph(graph, k=self.config.census.k)
        group = self._pending.setdefault(meta, [])
        if not group:
            self._first_seq[meta] = rid
        group.append((rid, graph))
        st = self._bucket_stats.setdefault(
            meta, dict(requests=0, batches=0, batched_graphs=0,
                       host_syncs=0, chunks=0))
        st["requests"] += 1
        if len(group) >= self.config.max_batch:
            self._flush_bucket(meta)
        # staleness: count only OTHER buckets' arrivals since a group's
        # oldest member — a hot bucket's own burst must still be allowed
        # to fill to max_batch.
        for stale in [m for m, s in self._first_seq.items()
                      if (self._seq - s - len(self._pending[m])
                          >= self.config.max_wait_requests)]:
            self._flush_bucket(stale)
        return rid

    def poll(self) -> List[CensusCompletion]:
        """Drain and return completions accumulated since the last poll.

        Order is batch flush order — generally NOT submission order; match
        on ``request_id``."""
        out, self._completed = self._completed, []
        return out

    def flush(self) -> List[CensusCompletion]:
        """Execute every pending partial group, then drain completions."""
        for meta in list(self._pending):
            self._flush_bucket(meta)
        return self.poll()

    def run_fleet(self, graphs: Iterable[CSRGraph]) -> List[CensusResult]:
        """Submit a whole fleet, flush, and return results in input order.

        Completions belonging to requests submitted *before* this call
        (drained by the flush) are retained for the next :meth:`poll` —
        never discarded.
        """
        ids = [self.submit(g) for g in graphs]
        mine = set(ids)
        done = {}
        others = []
        for c in self.flush():
            if c.request_id in mine:
                done[c.request_id] = c.result
            else:
                others.append(c)
        self._completed.extend(others)
        return [done[i] for i in ids]

    @property
    def pending(self) -> int:
        """Number of submitted-but-not-yet-executed requests."""
        return sum(len(g) for g in self._pending.values())

    # -- execution -----------------------------------------------------------

    def _flush_bucket(self, meta: GraphMeta) -> None:
        group = self._pending.pop(meta)
        self._first_seq.pop(meta)
        plan = compile_census(meta, self.config.census, mesh=self.mesh)
        before_sync = plan.stats["host_syncs"]
        before_chunks = plan.stats["chunks"]
        results = plan.run_batch([g for _, g in group])
        st = self._bucket_stats[meta]
        st["batches"] += 1
        st["batched_graphs"] += len(group)
        st["host_syncs"] += plan.stats["host_syncs"] - before_sync
        st["chunks"] += plan.stats["chunks"] - before_chunks
        self._completed.extend(
            CensusCompletion(rid, res, meta)
            for (rid, _), res in zip(group, results))

    # -- introspection -------------------------------------------------------

    def stats(self) -> dict:
        """Service-level + per-bucket serving statistics.

        ``buckets`` maps each :class:`GraphMeta` to its request/batch
        counts, ``occupancy`` (batched graphs per flushed batch slot —
        1.0 means every batch left full), and the host syncs / chunks its
        batches cost.  ``mean_batch`` is the fleet-wide average batch
        width — the dispatch amortization factor actually achieved.
        """
        buckets = {}
        total_batches = total_graphs = 0
        for meta, st in self._bucket_stats.items():
            occ = (st["batched_graphs"]
                   / (st["batches"] * self.config.max_batch)
                   if st["batches"] else 0.0)
            buckets[meta] = {**st, "occupancy": occ}
            total_batches += st["batches"]
            total_graphs += st["batched_graphs"]
        return dict(
            requests=self._seq,
            pending=self.pending,
            batches=total_batches,
            mean_batch=(total_graphs / total_batches
                        if total_batches else 0.0),
            buckets=buckets,
        )
