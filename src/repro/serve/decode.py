"""Serving steps: batched prefill and single-token decode over caches.

``serve_step`` is what the decode-shaped dry-run cells lower: one new token
per request against a ``seq_len``-deep KV/state cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..config.base import ModelConfig, RunConfig
from ..models.transformer import make_forward


def make_prefill_step(cfg: ModelConfig, run: RunConfig, mesh=None, rules=None):
    """Build the cacheless prefill step: ``(params, tokens) -> logits``.

    Runs the full forward over a ``(B, T)`` prompt batch without touching
    a decode cache — the shape the prefill-side dry-run cells lower.  Use
    :func:`make_prefill_cache_step` when decode will follow.
    """
    fwd = make_forward(cfg, run, mesh, rules)

    def prefill_step(params, tokens, positions=None, prefix_embeds=None):
        B, T = tokens.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None],
                                         (B, T))
        logits, _, _ = fwd(params, tokens, positions,
                           prefix_embeds=prefix_embeds)
        return logits

    return prefill_step


def make_prefill_cache_step(cfg: ModelConfig, run: RunConfig, mesh=None,
                            rules=None):
    """Prefill that also populates the decode cache (example/serving path)."""
    fwd = make_forward(cfg, run, mesh, rules)

    def prefill(params, tokens, cache, prefix_embeds=None):
        B, T = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None],
                                     (B, T))
        logits, new_cache, _ = fwd(params, tokens, positions,
                                   prefix_embeds=prefix_embeds, cache=cache,
                                   cache_pos=0)
        return logits, new_cache

    return prefill


def make_serve_step(cfg: ModelConfig, run: RunConfig, mesh=None, rules=None,
                    *, greedy: bool = True):
    """Build the single-token decode step:
    ``(params, cache, tokens, cache_pos[, rng]) -> (next, cache, logits)``.

    One new token per request against a ``seq_len``-deep KV/state cache;
    ``greedy=False`` samples from the logits with ``rng`` instead of
    argmax.  This is the unit ``examples/serve_decode.py`` jits and loops.
    """
    fwd = make_forward(cfg, run, mesh, rules)

    def serve_step(params, cache, tokens, cache_pos, rng: Optional[jax.Array] = None):
        """tokens: (B, 1) the newly generated token; cache_pos: () int32."""
        B = tokens.shape[0]
        positions = jnp.full((B, 1), cache_pos, jnp.int32)
        logits, new_cache, _ = fwd(params, tokens, positions, cache=cache,
                                   cache_pos=cache_pos)
        logits = logits[:, -1]
        if greedy or rng is None:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(rng, logits).astype(jnp.int32)
        return nxt[:, None], new_cache, logits

    return serve_step
