"""Evolving-graph serving: mutate a live graph, keep the census current.

The delta engine's pitch in one script — subscribe a graph once, stream
edge mutations at it, and every ``poll`` returns the exact census of the
current snapshot.  Each small mutation pays only two subset passes over
the dyads whose neighborhoods the edit touched (one device→host sync),
not a full recompute:

    PYTHONPATH=src python examples/evolving_graph.py [--backend xla]
"""
import argparse
import time

import numpy as np

from repro.core import GraphDelta, brute_force_census, generators
from repro.engine import EngineConfig, compile
from repro.serve import CensusService, ServiceConfig


def random_delta(g, rng, k=4):
    """k random arc insertions + k deletions of existing arcs."""
    out_ptr = np.asarray(g.arrays.out_ptr)[: g.n + 1]
    dst = np.asarray(g.arrays.out_idx)[: g.m].astype(np.int64)
    src = np.repeat(np.arange(g.n, dtype=np.int64), np.diff(out_ptr))
    sel = rng.choice(g.m, size=min(k, g.m), replace=False)
    return GraphDelta(edges_added=rng.integers(0, g.n, size=(k, 2)),
                      edges_removed=np.stack([src[sel], dst[sel]], 1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="xla",
                    choices=["xla", "pallas", "distributed", "auto"])
    ap.add_argument("--scale", type=int, default=10,
                    help="R-MAT scale (2**scale vertices)")
    ap.add_argument("--mutations", type=int, default=8)
    args = ap.parse_args()

    g = generators.rmat(args.scale, edge_factor=8, seed=0)
    cfg = EngineConfig(backend=args.backend)
    print(f"graph: n={g.n} arcs={g.m} dyads={g.n_dyads}")

    # plan-level API: apply_delta folds an exact integer correction
    plan = compile(g, ("triad_census",), cfg)
    raw = plan.run_raw(g)
    rng = np.random.default_rng(0)
    d = random_delta(g, rng)
    res = plan.apply_delta(g, d, raw)
    g2 = res.graph
    t0 = time.perf_counter()
    plan.apply_delta(g, d, raw)
    dt_delta = time.perf_counter() - t0
    t0 = time.perf_counter()
    full = plan.run_raw(g2)
    dt_full = time.perf_counter() - t0
    assert np.array_equal(res.raw, full)  # bit-identical, always
    print(f"\n{d.size}-arc delta touches "
          f"{res.affected_fraction:.2%} of all dyads: "
          f"apply_delta {dt_delta * 1e3:.1f} ms vs full recompute "
          f"{dt_full * 1e3:.1f} ms "
          f"({dt_full / max(dt_delta, 1e-9):.1f}x), mode={res.mode}")

    # service-level API: a subscribed session owns graph + plan + raw bins
    svc = CensusService(ServiceConfig(census=cfg))
    sid = svc.subscribe(g)
    t0 = time.perf_counter()
    for _ in range(args.mutations):
        ack = svc.mutate(sid, random_delta(svc._sessions[sid].graph, rng))
    dt = time.perf_counter() - t0
    print(f"\nsession {sid}: {args.mutations} mutations in "
          f"{dt * 1e3:.1f} ms "
          f"({args.mutations / max(dt, 1e-9):.1f} mutations/sec), "
          f"last ack mode={ack['mode']} n_arcs={ack['m']}")
    census = svc.poll(sid)
    print(f"current census: {census.counts.tolist()} "
          f"(total={int(census.counts.sum()):,})")
    stats = svc.stats()["sessions"][sid]
    print(f"session stats: {stats}")
    final = svc.unsubscribe(sid)
    assert np.array_equal(final.counts, census.counts)

    if g.n <= 256:  # oracle check, small graphs only
        g_small = generators.rmat(6, edge_factor=4, seed=1)
        s2 = svc.subscribe(g_small)
        svc.mutate(s2, random_delta(g_small, rng))
        live = svc._sessions[s2].graph
        assert np.array_equal(svc.poll(s2).counts,
                              brute_force_census(live).counts)
        svc.unsubscribe(s2)
    print("\npoll == exact census of the live snapshot, every time")


if __name__ == "__main__":
    main()
