"""Quickstart: the paper's algorithm + the LM framework in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.config import RunConfig, get_config
from repro.core import generators, pack_tasks
from repro.core.triad_table import TRIAD_NAMES
from repro.engine import CensusConfig, compile_census, plan_cache_stats
from repro.data import SyntheticTokens
from repro.models import transformer as tfm
from repro.train import adamw_init, make_train_step


def census_demo():
    print("== Triad census on an R-MAT power-law digraph ==")
    g = generators.rmat(10, edge_factor=8, seed=0)
    print(f"graph: n={g.n} arcs={g.m} max_deg={g.max_deg} dyads={g.n_dyads}")
    plan = compile_census(g, CensusConfig(backend="auto"))
    res = plan.run(g)
    # a same-shape graph reuses the compiled plan (the serving hot path)
    g2 = generators.rmat(10, edge_factor=8, seed=1)
    res2 = compile_census(g2, CensusConfig(backend="auto")).run(g2)
    cache = plan_cache_stats()
    print(f"second same-shape census: total={res2.total:,}; plan cache: "
          f"{ {k: cache[k] for k in ('hits', 'misses', 'size')} }")
    for name, c in zip(TRIAD_NAMES, res.counts):
        if c:
            print(f"  {name:5s} {c:>14,}")
    print(f"  total {res.total:,} == C(n,3) ✓")
    # the fused multi-analytic pass: more results, same traversal
    from repro.engine import EngineConfig, compile
    multi = compile(g, ["triad_census", "dyad_census", "triadic_profile"],
                    EngineConfig(backend="auto")).run(g)
    print(f"fused pass: {multi['dyad_census']}, transitivity="
          f"{multi['triadic_profile'].transitivity:.4f}")
    tasks = pack_tasks(g, 16, strategy="sorted_snake")
    print(f"16-shard balance (sorted_snake): imbalance={tasks.imbalance:.4f}")


def lm_demo():
    print("\n== 10-step LM training (qwen3-family smoke config) ==")
    cfg = get_config("qwen3-4b", smoke=True)
    run = RunConfig(attention_impl="chunked_causal", attention_chunk=16)
    params = tfm.init_model(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, run, warmup=5))
    ds = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    for i in range(10):
        params, opt, mets = step(params, opt,
                                 {"tokens": jnp.asarray(ds.batch_at(i))})
        print(f"  step {i}: loss={float(mets['loss']):.3f}")


if __name__ == "__main__":
    census_demo()
    lm_demo()
