"""Social-network-analysis scenario (the paper's use case, end to end).

Builds a network shaped like a Table 4.1 dataset, distributes the census
over every local device with the paper's task-queue balancing, and derives
the SNA statistics the census exists for (transitivity, reciprocity).

    PYTHONPATH=src python examples/triad_census_sna.py --dataset slashdot
    # multi-device: XLA_FLAGS=--xla_force_host_platform_device_count=8 ...
"""
import argparse

import jax

from repro.core import generators
from repro.core.triad_table import TRIAD_NAMES
from repro.engine import CensusConfig, compile_census


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="slashdot",
                    choices=sorted(generators.PAPER_DATASETS))
    ap.add_argument("--scale-down", type=float, default=256.0,
                    help="1.0 = full paper-sized graph (needs a pod)")
    ap.add_argument("--strategy", default="sorted_snake")
    ap.add_argument("--weights", default="canonical_uniform")
    args = ap.parse_args()

    g = generators.paper_profile(args.dataset, scale_down=args.scale_down)
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",))
    print(f"dataset={args.dataset} (R-MAT stand-in) n={g.n} m={g.m} "
          f"devices={n_dev}")

    cfg = CensusConfig(backend="distributed", strategy=args.strategy,
                       weight_model=args.weights)
    plan = compile_census(g, cfg, mesh=mesh)
    res = plan.run(g)
    print(f"load imbalance ({args.strategy}/{args.weights}): "
          f"{plan.last_task_stats.imbalance:.4f}")
    print("\ntriad census:")
    for name, c in zip(TRIAD_NAMES, res.counts):
        print(f"  {name:5s} {c:>16,}")

    c = res.counts.astype(float)
    # SNA statistics from the census (Wasserman-Faust style)
    # transitivity: fraction of potentially-transitive triads that are
    triads_2path = c[[4, 5, 6, 8, 9, 11, 12, 13, 14, 15]].sum()  # >=2 paths
    closed = c[[8, 11, 12, 13, 14, 15]].sum()
    mutual = 2 * c[2] + 2 * c[6] + 2 * c[7] + 4 * c[10] + 2 * c[11] + \
        2 * c[12] + 2 * c[13] + 4 * c[14] + 6 * c[15]
    print(f"\nclosed/connected ratio: {closed / max(triads_2path, 1):.4f}")
    print(f"reciprocity-weighted triads: {mutual:,.0f}")


if __name__ == "__main__":
    main()
