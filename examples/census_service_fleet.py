"""Fleet serving: many small-graph censuses per second, batched.

The SNA request pattern the service exists for: a stream of per-community
subgraphs (here R-MAT / Erdos-Renyi stand-ins) submitted one at a time.
The service groups them by plan-cache bucket and executes each group as
one vmapped batch — watch completions arrive out of submission order, and
compare the per-bucket occupancy + host-sync counts against what B
individual ``plan.run`` calls would have cost.

    PYTHONPATH=src python examples/census_service_fleet.py --fleet 24
"""
import argparse
import time

from repro.core import generators
from repro.engine import CensusConfig, plan_cache_stats
from repro.serve import CensusService, ServiceConfig


def build_fleet(n: int):
    """A mixed fleet: two small-graph populations, several meta buckets."""
    fleet = []
    for i in range(n):
        if i % 3 == 2:
            fleet.append(generators.erdos_renyi(48, 96, seed=i))
        else:
            fleet.append(generators.rmat(5, edge_factor=2, seed=i))
    return fleet


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fleet", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait", type=int, default=12,
                    help="force-flush a partial bucket after this many "
                         "other-bucket submissions (bounded staleness)")
    args = ap.parse_args()

    cfg = ServiceConfig(max_batch=args.max_batch,
                        max_wait_requests=args.max_wait,
                        census=CensusConfig(backend="xla", batch=64,
                                            chunk_dyads=64))
    svc = CensusService(cfg)
    fleet = build_fleet(args.fleet)

    print(f"submitting {len(fleet)} requests "
          f"(max_batch={args.max_batch}, max_wait={args.max_wait}; every "
          f"4th asks for a fused census+degree_stats pass) ...")

    def describe(c):
        if isinstance(c.result, dict):  # multi-op request
            ds = c.result["degree_stats"]
            return (f"total={c.result['triad_census'].total:,} "
                    f"max_out={ds.max_out}")
        return f"total={c.result.total:,}"

    t0 = time.perf_counter()
    for i, g in enumerate(fleet):
        # a mixed-analytic stream: groups batch by (bucket, ops) key
        ops = ("triad_census", "degree_stats") if i % 4 == 3 else None
        rid = svc.submit(g, ops)
        for c in svc.poll():  # completions surface in batch flush order
            print(f"  completed request {c.request_id:>3} "
                  f"(bucket n<={c.meta.n_bucket}, k={c.meta.k}, "
                  f"ops={'+'.join(c.ops)}): {describe(c)}")
    for c in svc.flush():  # drain the partial groups
        print(f"  completed request {c.request_id:>3} (drain): "
              f"{describe(c)}")
    dt = time.perf_counter() - t0

    st = svc.stats()
    print(f"\n{st['requests']} requests in {dt:.2f}s "
          f"({st['requests'] / dt:.0f} req/s incl. compile) — "
          f"{st['batches']} batches, mean width {st['mean_batch']:.1f}")
    for meta, b in st["buckets"].items():
        print(f"  bucket(n<={meta.n_bucket}, k={meta.k}): "
              f"{b['requests']} reqs in {b['batches']} batches, "
              f"occupancy {b['occupancy']:.2f}, "
              f"host_syncs {b['host_syncs']} "
              f"(sequential would have paid {b['requests']})")
    cache = plan_cache_stats()
    print(f"plan cache: {cache['size']} plans, hits={cache['hits']} "
          f"misses={cache['misses']}")


if __name__ == "__main__":
    main()
