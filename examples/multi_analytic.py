"""Fused multi-analytic pass: one traversal, four results.

The GraphOp layer's pitch in one script — the whole triadic-analysis
family (triad census, MAN dyad census, degree statistics, transitivity
profile) computed from ONE pass over the streaming dyad pipeline, with
one device→host transfer, exactly what a census-only run costs:

    PYTHONPATH=src python examples/multi_analytic.py [--backend xla]
"""
import argparse
import time

from repro.core import generators
from repro.core.triad_table import TRIAD_NAMES
from repro.engine import EngineConfig, compile, list_ops

OPS = ["triad_census", "dyad_census", "degree_stats", "triadic_profile"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="xla",
                    choices=["xla", "pallas", "distributed", "auto"])
    ap.add_argument("--scale", type=int, default=10,
                    help="R-MAT scale (2**scale vertices)")
    args = ap.parse_args()

    g = generators.rmat(args.scale, edge_factor=8, seed=0)
    print(f"graph: n={g.n} arcs={g.m} dyads={g.n_dyads}; "
          f"registered ops: {list_ops()}")

    # the two-line multi-op call
    cfg = EngineConfig(backend=args.backend)
    plan = compile(g, OPS, cfg)

    t0 = time.perf_counter()
    res = plan.run(g)
    dt = time.perf_counter() - t0
    print(f"\nfused {len(OPS)}-op pass: {dt * 1e3:.1f} ms, "
          f"host_syncs={plan.stats['host_syncs']} "
          f"(a census-only run costs the same)")

    census = res["triad_census"]
    top = sorted(zip(TRIAD_NAMES, census.counts), key=lambda x: -x[1])[:5]
    print("\ntriad_census (top types):",
          ", ".join(f"{nm}={int(c):,}" for nm, c in top if c))
    dy = res["dyad_census"]
    print(f"dyad_census: mutual={dy.mutual:,} asymmetric={dy.asymmetric:,} "
          f"null={dy.null:,}")
    ds = res["degree_stats"]
    print(f"degree_stats: max_out={ds.max_out} max_in={ds.max_in} "
          f"mean={ds.mean_out:.2f}; out-degree log2 histogram="
          f"{ds.out_hist.tolist()}")
    tp = res["triadic_profile"]
    print(f"triadic_profile: triangles={tp.triangles:,} "
          f"open_triples={tp.open_triples:,} "
          f"transitivity={tp.transitivity:.4f}")

    # the fused pass vs four separate passes over the same stream
    solo_plans = [compile(g, [name], cfg) for name in OPS]
    for p in solo_plans:
        p.run(g)  # compile outside the timed region
    t0 = time.perf_counter()
    for p in solo_plans:
        p.run(g)
    separate = time.perf_counter() - t0
    t0 = time.perf_counter()
    plan.run(g)
    fused = time.perf_counter() - t0
    print(f"\nwarm fused pass {fused * 1e3:.1f} ms vs separate passes "
          f"{separate * 1e3:.1f} ms -> {separate / max(fused, 1e-9):.2f}x")


if __name__ == "__main__":
    main()
