"""Batched serving: prefill populates the cache, then token-by-token decode.

    PYTHONPATH=src python examples/serve_decode.py --arch rwkv6-3b --new 24
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import RunConfig, get_config
from repro.models import transformer as tfm
from repro.serve import make_serve_step
from repro.serve.decode import make_prefill_cache_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    run = RunConfig(attention_impl="chunked_causal", attention_chunk=32,
                    remat="none")
    params = tfm.init_model(cfg, jax.random.PRNGKey(0))
    B, P = args.batch, args.prompt_len
    max_seq = P + args.new
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                 cfg.vocab_size)

    prefill = jax.jit(make_prefill_cache_step(cfg, run))
    serve = jax.jit(make_serve_step(cfg, run))

    cache = tfm.init_cache(cfg, B, max_seq)
    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts, cache)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    print(f"prefill {B}x{P} in {time.perf_counter()-t0:.2f}s")

    out = [tok]
    t0 = time.perf_counter()
    for i in range(args.new - 1):
        tok, cache, _ = serve(params, cache, tok, jnp.int32(P + i))
        out.append(tok)
    dt = time.perf_counter() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decoded {args.new-1} tokens/request in {dt:.2f}s "
          f"({B*(args.new-1)/max(dt,1e-9):.1f} tok/s batch throughput)")
    for b in range(min(B, 2)):
        print(f"  request {b}: {gen[b].tolist()}")


if __name__ == "__main__":
    main()
