"""End-to-end LM training driver: checkpointing, auto-resume, watchdog.

Default args train a ~10M-param model for 60 steps on CPU in minutes; on a
real pod raise --width/--layers/--steps (e.g. --width 768 --layers 12 for
~100M) and it is the same code path as launch/train.py.

    PYTHONPATH=src python examples/train_lm.py --steps 60
    # kill it mid-run and re-run: it resumes from the latest checkpoint.
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.config import RunConfig, get_config
from repro.data import SyntheticTokens
from repro.models import transformer as tfm
from repro.train import CheckpointManager, adamw_init, make_train_step
from repro.train.elastic import StepWatchdog
from repro.train.optimizer import OptState


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--width", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    args = ap.parse_args()

    base = get_config(args.arch, smoke=True)
    cfg = dataclasses.replace(
        base, n_layers=args.layers, d_model=args.width,
        n_heads=max(4, args.width // 64), n_kv_heads=max(2, args.width // 128),
        d_ff=args.width * 4, head_dim=None, vocab_size=4096)
    run = RunConfig(attention_impl="chunked_causal", attention_chunk=128,
                    remat="full", learning_rate=args.lr)
    print(f"model: {cfg.n_layers}L d={cfg.d_model} "
          f"params={cfg.param_count()/1e6:.1f}M")

    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    step_fn = jax.jit(make_train_step(cfg, run, total_steps=args.steps,
                                      warmup=max(args.steps // 10, 2)))
    ds = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=args.seq,
                         global_batch=args.batch)

    start = mgr.latest_step()
    if start is not None:
        trees, meta = mgr.restore(start)
        params = trees["params"]
        opt = OptState(step=jnp.int32(start), m=trees["m"], v=trees["v"])
        print(f"resumed from checkpoint step {start}")
    else:
        params = tfm.init_model(cfg, jax.random.PRNGKey(0))
        opt = adamw_init(params)
        start = 0

    wd = StepWatchdog()
    for i in range(start, args.steps):
        wd.start()
        batch = {"tokens": jnp.asarray(ds.batch_at(i))}
        params, opt, mets = step_fn(params, opt, batch)
        straggler = wd.stop(i)
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(mets['loss']):.4f} "
                  f"gnorm={float(mets['grad_norm']):.3f} "
                  f"lr={float(mets['lr']):.2e}"
                  + ("  [straggler]" if straggler else ""))
        if (i + 1) % args.ckpt_every == 0:
            mgr.save(i + 1, {"params": params, "m": opt.m, "v": opt.v},
                     meta={"step": i + 1})
    mgr.wait()
    print(f"done; checkpoints at {args.ckpt_dir}: {mgr.all_steps()}")
    if wd.stragglers:
        print(f"straggling steps flagged: {wd.stragglers}")


if __name__ == "__main__":
    main()
