"""Fault injection + recovery: deterministic FaultPlan replay, bounded
chunk retry with bit-identical recovered results on every backend and
schedule, device-loss quarantine and the dynamic→static rung, the
pallas→xla compile/runtime rungs, poison-batch isolation and admission
control in the serve layer, session rollback on mid-mutate failure, and
the REPRO_FAULT_PLAN environment hook — all clockless and seeded, so
every failing scenario replays exactly."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import brute_force_census, generators
from repro.core.delta import GraphDelta
from repro.engine import (ChunkRetryError, DeviceLostError, EngineConfig,
                          FaultPlan, InjectedFault, WorkerFailures,
                          clear_plan_cache, compile, is_poisoned,
                          plan_cache_stats, poison, resolve_faults, unpoison)
from repro.engine.executor import _raise_worker_errors
from repro.serve import (AdmissionError, CensusService, DeadlineExceeded,
                         ServiceConfig)

BACKENDS = ["xla", "pallas", "distributed"]
SRC = os.path.join(os.path.dirname(__file__), "..", "src")

#: explicit inert plan: opts OUT of any REPRO_FAULT_PLAN chaos-CI
#: environment plan, so "clean" baselines stay clean under chaos runs.
CLEAN = FaultPlan()

#: recoverable chunk chaos: every selected chunk fails exactly its first
#: attempt (fail_attempts=1 < max_attempts default 3), so recovery is
#: deterministic and total.
CHAOS = FaultPlan(seed=3, chunk_failure_rate=0.5, fail_attempts=1)


@pytest.fixture(autouse=True)
def _fresh_cache():
    clear_plan_cache()
    yield
    clear_plan_cache()


def _graph():
    return generators.rmat(7, edge_factor=4, seed=11)


# ----------------------------------------------------------------------------
# FaultPlan mechanics: validation, determinism, inertness, resolution
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("kwargs,match", [
    (dict(chunk_failure_rate=1.5), "chunk_failure_rate"),
    (dict(slow_chunk_rate=-0.1), "slow_chunk_rate"),
    (dict(fail_attempts=0), "fail_attempts"),
    (dict(device_loss=(-1,)), "device_loss"),
    (dict(device_loss_after=-1), "device_loss_after"),
    (dict(compile_failure=("cuda",)), "unknown backends"),
    (dict(runtime_failure=("nope",)), "unknown backends"),
    (dict(mutate_failure_calls=(-2,)), "mutate_failure_calls"),
    (dict(slow_s=-1.0), "slow_s"),
])
def test_fault_plan_knob_validation(kwargs, match):
    with pytest.raises(ValueError, match=match):
        FaultPlan(**kwargs)


@pytest.mark.parametrize("kwargs,match", [
    (dict(max_attempts=0), "max_attempts"),
    (dict(backend_fallback="yes"), "backend_fallback"),
    (dict(schedule_fallback=1), "schedule_fallback"),
    (dict(fault_plan="chaos"), "fault_plan"),
])
def test_engine_config_fault_knob_validation(kwargs, match):
    with pytest.raises(ValueError, match=match):
        EngineConfig(**kwargs)


def test_fault_plan_is_deterministic_and_hashable():
    a = FaultPlan(seed=9, chunk_failure_rate=0.3, device_loss=[1, 2])
    b = FaultPlan(seed=9, chunk_failure_rate=0.3, device_loss=(1, 2))
    assert a == b and hash(a) == hash(b)  # list input normalized to tuple
    # pure counter hash: same (seed, chunk) decision from any instance,
    # any call order, no RNG state consumed anywhere.
    decisions = [a.chunk_fails(s, 1) for s in range(0, 4096, 64)]
    assert decisions == [b.chunk_fails(s, 1) for s in range(0, 4096, 64)]
    assert any(decisions) and not all(decisions)
    # a different seed is a different schedule
    c = FaultPlan(seed=10, chunk_failure_rate=0.3)
    assert decisions != [c.chunk_fails(s, 1) for s in range(0, 4096, 64)]
    # attempts past fail_attempts succeed (the recoverability contract)
    start = next(s for s in range(0, 4096, 64) if a.chunk_fails(s, 1))
    assert not a.chunk_fails(start, 2)


def test_inert_plan_resolution_and_env_opt_out():
    assert FaultPlan().is_inert
    assert not CHAOS.is_inert
    # an explicitly inert plan resolves to None (skip injection checks
    # entirely), a live plan resolves to itself.
    assert resolve_faults(CLEAN) is None
    assert resolve_faults(CHAOS) is CHAOS


def test_poison_registry_is_identity_based():
    g, twin = _graph(), _graph()
    poison(g)
    try:
        assert is_poisoned(g)
        assert not is_poisoned(twin)  # structurally equal copy unaffected
    finally:
        unpoison(g)
    assert not is_poisoned(g)


# ----------------------------------------------------------------------------
# recovery: retried runs are bit-identical to fault-free, one sync
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("schedule", ["static", "dynamic"])
def test_recovered_run_bit_identical_one_sync(backend, schedule):
    g = _graph()
    want = brute_force_census(g).counts
    cfg = EngineConfig(backend=backend, batch=16, chunk_dyads=64,
                       schedule=schedule, fault_plan=CHAOS)
    plan = compile(g, ("triad_census", "dyad_census"), cfg)
    res = plan.run(g)
    assert np.array_equal(res["triad_census"].counts, want)
    fs = plan.stats["faults"]
    assert fs["chunk_failures"] > 0, "chaos plan never fired — dead test"
    assert fs["retries"] > 0
    assert plan.stats["host_syncs"] == 1  # recovery costs no extra sync
    assert sum(plan.stats["device_chunks"].values()) == plan.stats["chunks"]
    # bit-identity against an explicitly clean plan
    clean = compile(g, ("triad_census", "dyad_census"),
                    EngineConfig(backend=backend, batch=16, chunk_dyads=64,
                                 schedule=schedule, fault_plan=CLEAN))
    clean_res = clean.run(g)
    assert np.array_equal(res["triad_census"].counts,
                          clean_res["triad_census"].counts)
    assert res["dyad_census"] == clean_res["dyad_census"]
    assert clean.stats["faults"]["chunk_failures"] == 0


def test_same_seed_replays_identical_fault_trace():
    g = _graph()
    traces = []
    for _ in range(2):
        clear_plan_cache()  # force a fresh plan (same config = same entry)
        plan = compile(g, ("triad_census",),
                       EngineConfig(backend="xla", batch=16, chunk_dyads=64,
                                    fault_plan=CHAOS))
        plan.run(g)
        traces.append((list(plan.stats["fault_events"]),
                       dict(plan.stats["faults"])))
    # static schedule: the whole trace — order included — replays exactly
    assert traces[0] == traces[1]
    assert any(e[0] == "chunk_failure" for e in traces[0][0])


def test_retry_exhaustion_raises_chunk_retry_error():
    g = _graph()
    # fail_attempts >= max_attempts: the selected chunks can never recover
    cfg = EngineConfig(backend="xla", batch=16, chunk_dyads=64,
                       max_attempts=2,
                       fault_plan=FaultPlan(seed=3, chunk_failure_rate=0.5,
                                            fail_attempts=99))
    plan = compile(g, ("triad_census",), cfg)
    with pytest.raises(ChunkRetryError) as exc:
        plan.run(g)
    assert len(exc.value.attempts) == 2  # the full dispatch budget
    assert isinstance(exc.value.__cause__, InjectedFault)


def test_max_attempts_one_disables_retry():
    g = _graph()
    cfg = EngineConfig(backend="xla", batch=16, chunk_dyads=64,
                       max_attempts=1, fault_plan=CHAOS)
    plan = compile(g, ("triad_census",), cfg)
    with pytest.raises(ChunkRetryError):
        plan.run(g)
    assert plan.stats["faults"]["retries"] == 0


# ----------------------------------------------------------------------------
# the degradation ladder
# ----------------------------------------------------------------------------

def test_device_loss_takes_dynamic_to_static_rung():
    g = _graph()
    want = brute_force_census(g).counts
    cfg = EngineConfig(backend="xla", batch=16, chunk_dyads=64,
                       schedule="dynamic", n_executor_devices=1,
                       fault_plan=FaultPlan(seed=1, device_loss=(0,)))
    plan = compile(g, ("triad_census",), cfg)
    res = plan.run(g)
    assert np.array_equal(res["triad_census"].counts, want)
    fs = plan.stats["faults"]
    assert fs["device_losses"] >= 1
    assert fs["schedule_fallbacks"] == 1
    assert plan.stats["host_syncs"] == 1  # the rung restarts, then 1 sync
    assert any(e[0] == "schedule_fallback"
               for e in plan.stats["fault_events"])


def test_schedule_fallback_disabled_surfaces_the_loss():
    g = _graph()
    cfg = EngineConfig(backend="xla", batch=16, chunk_dyads=64,
                       schedule="dynamic", n_executor_devices=1,
                       schedule_fallback=False,
                       fault_plan=FaultPlan(seed=1, device_loss=(0,)))
    plan = compile(g, ("triad_census",), cfg)
    with pytest.raises(ChunkRetryError) as exc:
        plan.run(g)
    assert isinstance(exc.value.__cause__, DeviceLostError)


def test_pallas_compile_failure_demotes_to_xla():
    g = _graph()
    want = brute_force_census(g).counts
    cfg = EngineConfig(backend="pallas", batch=16, chunk_dyads=64,
                       fault_plan=FaultPlan(compile_failure=("pallas",)))
    plan = compile(g, ("triad_census",), cfg)
    assert plan.requested_backend == "pallas"
    assert plan.backend == "xla"  # demoted at build time
    assert plan.degradation and plan.degradation[0]["rung"] == "pallas->xla"
    assert plan.degradation[0]["stage"] == "compile"
    res = plan.run(g)
    assert np.array_equal(res["triad_census"].counts, want)
    assert plan.stats["faults"]["backend_fallbacks"] == 1
    # the ladder is introspectable from the cache, not just the plan
    entry = [e for e in plan_cache_stats()["entries"]
             if e["requested_backend"] == "pallas"]
    assert entry and entry[0]["degradation"][0]["stage"] == "compile"


def test_pallas_runtime_failure_demotes_to_xla():
    g = _graph()
    want = brute_force_census(g).counts
    cfg = EngineConfig(backend="pallas", batch=16, chunk_dyads=64,
                       fault_plan=FaultPlan(runtime_failure=("pallas",)))
    plan = compile(g, ("triad_census",), cfg)
    assert plan.backend == "pallas"  # compiles fine, fails at dispatch
    res = plan.run(g)
    assert plan.backend == "xla"
    assert np.array_equal(res["triad_census"].counts, want)
    assert plan.degradation[0]["stage"] == "runtime"
    # the demoted plan keeps serving (no re-demotion, stable results)
    res2 = plan.run(g)
    assert np.array_equal(res2["triad_census"].counts, want)
    assert plan.stats["faults"]["backend_fallbacks"] == 1


def test_backend_fallback_disabled_reraises():
    g = _graph()
    cfg = EngineConfig(backend="pallas", batch=16, chunk_dyads=64,
                       backend_fallback=False,
                       fault_plan=FaultPlan(compile_failure=("pallas",)))
    with pytest.raises(InjectedFault):
        compile(g, ("triad_census",), cfg)


def test_faulty_and_clean_configs_never_share_plans():
    g = _graph()
    faulty = compile(g, ("triad_census",),
                     EngineConfig(backend="xla", fault_plan=CHAOS))
    clean = compile(g, ("triad_census",),
                    EngineConfig(backend="xla", fault_plan=CLEAN))
    assert faulty is not clean
    assert len(plan_cache_stats()["entries"]) == 2


def test_raise_worker_errors_attaches_secondaries():
    e1, e2, e3 = RuntimeError("a"), RuntimeError("b"), RuntimeError("c")
    with pytest.raises(RuntimeError, match="a") as exc:
        _raise_worker_errors([e1, e2, e3])
    assert isinstance(exc.value.__cause__, WorkerFailures)
    assert exc.value.__cause__.errors == [e2, e3]  # nothing dropped
    solo = RuntimeError("solo")
    with pytest.raises(RuntimeError, match="solo") as exc:
        _raise_worker_errors([solo])
    assert exc.value.__cause__ is None  # single failure stays plain


# ----------------------------------------------------------------------------
# serve-layer hardening: isolation, admission, deadlines, rollback
# ----------------------------------------------------------------------------

def _svc_cfg(**kw):
    census = kw.pop("census", EngineConfig(backend="xla", fault_plan=CLEAN))
    return ServiceConfig(census=census, **kw)


def test_poison_graph_fails_alone_peers_complete():
    g1, bad, g3 = (generators.rmat(6, edge_factor=4, seed=s)
                   for s in (1, 2, 3))
    svc = CensusService(_svc_cfg(max_batch=8))
    poison(bad)
    try:
        rids = [svc.submit(g) for g in (g1, bad, g3)]
        comps = {c.request_id: c for c in svc.flush()}
    finally:
        unpoison(bad)
    assert isinstance(comps[rids[1]].error, InjectedFault)
    assert comps[rids[1]].result is None
    for rid, g in ((rids[0], g1), (rids[2], g3)):
        assert comps[rid].error is None
        assert np.array_equal(comps[rid].result.counts,
                              brute_force_census(g).counts)
    health = svc.stats()["health"]
    assert health["poisoned"] == 1
    assert health["batch_failures"] == 1  # the vmapped unit retried member-wise
    assert svc.pending == 0


def test_admission_reject_policy():
    g = _graph()
    svc = CensusService(_svc_cfg(max_batch=8, max_pending=2))
    svc.submit(g)
    svc.submit(g)
    with pytest.raises(AdmissionError):
        svc.submit(g)
    assert svc.stats()["health"]["rejections"] == 1
    assert svc.pending == 2  # the rejected request took no state
    svc.flush()


def test_admission_flush_oldest_policy():
    g = _graph()
    svc = CensusService(_svc_cfg(max_batch=8, max_pending=2,
                                 reject_policy="flush_oldest"))
    rids = [svc.submit(g) for _ in range(4)]  # each overflow flushes
    assert svc.pending <= 2
    comps = {c.request_id for c in svc.flush()}
    assert comps == set(rids)  # every admitted request completed


def test_deadline_rounds_expire_clocklessly():
    small, big = _graph(), generators.rmat(9, edge_factor=4, seed=5)
    svc = CensusService(_svc_cfg(max_batch=8))
    with pytest.raises(ValueError, match="deadline_rounds"):
        svc.submit(small, deadline_rounds=-1)
    doomed = svc.submit(small, deadline_rounds=0)
    svc.submit(big)  # a different bucket: its flush advances the round
    big_key = next(k for k in list(svc._pending)
                   if svc._pending[k][0].rid != doomed)
    svc._flush_group(big_key)
    comps = {c.request_id: c for c in svc.flush()}
    assert isinstance(comps[doomed].error, DeadlineExceeded)
    assert comps[doomed].result is None
    st = svc.stats()
    assert st["health"]["expired"] == 1
    assert st["rounds"] >= 1
    assert svc.pending == 0


def test_mutate_failure_rolls_session_back():
    g = _graph()
    fp = FaultPlan(mutate_failure_calls=(1,))  # second application dies
    svc = CensusService(_svc_cfg(
        census=EngineConfig(backend="xla", fault_plan=fp)))
    sid = svc.subscribe(g)
    d = GraphDelta(edges_added=np.array([[0, 1], [2, 3], [4, 5]]))
    svc.mutate(sid, d)  # application #0 succeeds
    want = svc.poll(sid).counts
    d2 = GraphDelta(edges_added=np.array([[6, 7]]))
    with pytest.raises(InjectedFault):
        svc.mutate(sid, d2)  # application #1: injected mid-mutate failure
    # the session served its pre-failure state — graph, raw bins, counts
    assert np.array_equal(svc.poll(sid).counts, want)
    st = svc.stats()
    assert st["sessions"][sid]["failed"] == 1
    assert st["health"]["mutate_failures"] == 1
    # the failed ordinal is consumed: the retry proceeds and commits
    svc.mutate(sid, d2)
    assert svc.stats()["sessions"][sid]["mutations"] == 2


def test_dynamic_flush_records_dead_group_explicitly():
    # satellite regression: a group whose flush thread dies must fail its
    # requests explicitly — error completions, pending drained — while
    # peer groups' results are recorded normally.
    small, big = _graph(), generators.rmat(9, edge_factor=4, seed=5)
    svc = CensusService(_svc_cfg(
        max_batch=8,
        census=EngineConfig(backend="xla", schedule="dynamic",
                            fault_plan=CLEAN)))
    ok = svc.submit(small)
    doomed = svc.submit(big)
    doomed_key = next(k for k in list(svc._pending)
                      if svc._pending[k][0].rid == doomed)
    real = svc._execute_group

    def sabotaged(plan, group, _real=real, _key=doomed_key):
        if group[0].rid == doomed:
            raise RuntimeError("group thread died mid-flush")
        return _real(plan, group)

    svc._execute_group = sabotaged
    comps = {c.request_id: c for c in svc.flush()}
    assert svc.pending == 0  # nothing stuck in pending, ever
    assert comps[ok].error is None
    assert np.array_equal(comps[ok].result.counts,
                          brute_force_census(small).counts)
    assert isinstance(comps[doomed].error, RuntimeError)
    assert svc.stats()["health"]["group_failures"] == 1


def test_service_stats_expose_health_and_fallbacks():
    g = _graph()
    svc = CensusService(_svc_cfg(
        census=EngineConfig(backend="xla", chunk_dyads=64, batch=16,
                            fault_plan=CHAOS)))
    rid = svc.submit(g)
    comps = {c.request_id: c for c in svc.flush()}
    assert comps[rid].error is None  # chaos is recoverable, request served
    health = svc.stats()["health"]
    assert set(health) >= {"retries", "quarantines", "backend_fallbacks",
                           "schedule_fallbacks", "rejections", "poisoned",
                           "expired", "batch_failures", "group_failures",
                           "mutate_failures"}
    assert health["retries"] > 0  # engine recoveries aggregate upward
    assert health["poisoned"] == 0


# ----------------------------------------------------------------------------
# environment hook + the real multi-device pool (subprocesses)
# ----------------------------------------------------------------------------

def test_env_fault_plan_governs_default_configs():
    code = """
import numpy as np
from repro.core import brute_force_census, generators
from repro.engine import EngineConfig, FaultPlan, compile, fault_plan_from_env
plan_env = fault_plan_from_env()
assert plan_env is not None and plan_env.seed == 3
g = generators.rmat(7, edge_factor=4, seed=11)
want = brute_force_census(g).counts
# default config (fault_plan=None) inherits the environment chaos...
chaos = compile(g, ("triad_census",),
                EngineConfig(backend="xla", batch=16, chunk_dyads=64))
assert np.array_equal(chaos.run(g)["triad_census"].counts, want)
assert chaos.stats["faults"]["retries"] > 0
# ...and an explicitly inert plan opts out, even under the env hook.
quiet = compile(g, ("triad_census",),
                EngineConfig(backend="xla", batch=16, chunk_dyads=64,
                             fault_plan=FaultPlan()))
assert np.array_equal(quiet.run(g)["triad_census"].counts, want)
assert quiet.stats["faults"]["chunk_failures"] == 0
print('OK')
"""
    env = {**os.environ, "PYTHONPATH": SRC,
           "REPRO_FAULT_PLAN":
               '{"seed": 3, "chunk_failure_rate": 0.5, "fail_attempts": 1}'}
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_env_fault_plan_rejects_malformed_json():
    code = """
from repro.engine import fault_plan_from_env
try:
    fault_plan_from_env()
except ValueError as e:
    assert 'REPRO_FAULT_PLAN' in str(e)
    print('OK')
"""
    env = {**os.environ, "PYTHONPATH": SRC,
           "REPRO_FAULT_PLAN": '{"no_such_knob": 1}'}
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_device_loss_quarantine_on_real_pool():
    # forced 8 host devices (the flag must precede jax init): lose one
    # device mid-run AND sprinkle recoverable chunk failures — the
    # survivors absorb the re-queued work, the result stays bit-identical
    # in one sync, and the loss/quarantine land in the fault counters.
    code = """
import numpy as np, jax
assert len(jax.devices()) == 8
from repro.core import brute_force_census, generators
from repro.engine import EngineConfig, FaultPlan, compile
g = generators.rmat(8, edge_factor=6, seed=11)
want = brute_force_census(g).counts
# device 2 is dead on arrival: it can never fold a chunk, so the moment
# its worker pulls a task the loss + quarantine fire.  Whether that
# worker wins a task at all is a thread race against the queue draining,
# so run the (cheap, warm) plan a few times — each run re-races — and
# require the loss to land within the budget.
plan = compile(g, ("triad_census",),
               EngineConfig(backend="xla", batch=16, chunk_dyads=32,
                            schedule="dynamic",
                            fault_plan=FaultPlan(seed=3,
                                                 chunk_failure_rate=0.2,
                                                 fail_attempts=1,
                                                 device_loss=(2,))))
runs = 0
for _ in range(8):
    res = plan.run(g)
    runs += 1
    assert np.array_equal(res["triad_census"].counts, want)
    if plan.stats["faults"]["device_losses"]:
        break
fs = plan.stats["faults"]
assert fs["device_losses"] >= 1 and fs["quarantines"] >= 1, fs
assert fs["schedule_fallbacks"] == 0, fs  # survivors finished the queue
assert plan.stats["host_syncs"] == runs  # recovery never adds a sync
assert sum(plan.stats["device_chunks"].values()) == plan.stats["chunks"]
assert 2 not in plan.stats["device_chunks"]  # the dead device folded nothing
print('OK')
"""
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": SRC}
    env.pop("REPRO_FAULT_PLAN", None)  # the inline plan is the fixture
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


# ----------------------------------------------------------------------------
# concurrent-shard (partitioned) fault paths
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["pool", "serial"])
def test_partitioned_chunk_chaos_recovers_bit_identical(mode):
    # recoverable chunk failures inside a partitioned run: the executor's
    # bounded retry recovers every shard's chunks, the merged result is
    # bit-identical to a clean unpartitioned pass, still ONE sync.
    g = _graph()
    want = brute_force_census(g).counts
    plan = compile(g, ("triad_census",),
                   EngineConfig(backend="xla", batch=16, chunk_dyads=64,
                                partitions=4, partition_mode=mode,
                                fault_plan=CHAOS))
    res = plan.run(g)
    assert np.array_equal(res["triad_census"].counts, want)
    fs = plan.stats["faults"]
    assert fs["chunk_failures"] > 0 and fs["retries"] > 0
    assert plan.stats["host_syncs"] == 1
    ps = plan.stats["partition"]
    assert ps["mode"] == mode
    # staging stays hoisted even under chaos: retries reuse the resident
    # context, they never re-stage it.
    assert ps["h2d_puts"] == sum(1 for d in ps["shard_dyads"] if d)


def test_partitioned_pool_device_loss_falls_back_bit_identical():
    # a 1-wide pool loses its only device mid-shard: the pinned rung
    # re-runs the shard from its seed with loss injection suppressed,
    # re-staging via rebuild() — recovered results stay bit-identical.
    g = _graph()
    want = brute_force_census(g).counts
    plan = compile(g, ("triad_census",),
                   EngineConfig(backend="xla", batch=16, chunk_dyads=64,
                                schedule="dynamic", n_executor_devices=1,
                                partitions=4, partition_mode="pool",
                                fault_plan=FaultPlan(seed=1,
                                                     device_loss=(0,))))
    res = plan.run(g)
    assert np.array_equal(res["triad_census"].counts, want)
    fs = plan.stats["faults"]
    assert fs["device_losses"] >= 1
    assert fs["schedule_fallbacks"] >= 1
    assert plan.stats["host_syncs"] == 1
    assert any(e[0] == "schedule_fallback"
               for e in plan.stats["fault_events"])
