"""End-to-end behaviour tests for the framework."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import RunConfig, get_config, list_configs
from repro.data import SyntheticTokens
from repro.models import transformer as tfm
from repro.train import adamw_init, make_train_step


def test_all_archs_registered():
    assert len(list_configs()) == 10
    for name in list_configs():
        cfg = get_config(name)
        smoke = get_config(name, smoke=True)
        assert cfg.name == name
        assert smoke.param_count() < 10_000_000


def test_param_counts_match_published_sizes():
    expect = {
        "zamba2-1.2b": (1.2, 0.25),
        "qwen1.5-4b": (3.95, 0.15),
        "qwen3-4b": (4.0, 0.15),
        "deepseek-coder-33b": (33.0, 0.1),
        "pixtral-12b": (12.0, 0.1),
        "deepseek-v2-236b": (236.0, 0.05),
        "granite-moe-3b-a800m": (3.3, 0.15),
        "rwkv6-3b": (3.1, 0.2),
    }
    for arch, (b, tol) in expect.items():
        n = get_config(arch).param_count() / 1e9
        assert abs(n - b) / b < tol, (arch, n, b)


def test_train_loop_learns():
    import dataclasses
    cfg = dataclasses.replace(get_config("qwen3-4b", smoke=True),
                              vocab_size=64)
    run = RunConfig(attention_impl="chunked_causal", attention_chunk=16,
                    remat="full", learning_rate=1e-3)
    params = tfm.init_model(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, run, microbatch=2, warmup=5))
    ds = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    losses = []
    for i in range(25):
        params, opt, mets = step(params, opt,
                                 {"tokens": jnp.asarray(ds.batch_at(i))})
        losses.append(float(mets["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses[::6]
    assert np.isfinite(losses).all()


def test_grad_compression_still_learns():
    import dataclasses
    cfg = dataclasses.replace(get_config("qwen3-4b", smoke=True),
                              vocab_size=64)
    run = RunConfig(attention_impl="chunked_causal", attention_chunk=16,
                    remat="none", learning_rate=1e-3, grad_compression="int8")
    params = tfm.init_model(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, run, warmup=5))
    ds = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    losses = []
    for i in range(25):
        params, opt, mets = step(params, opt,
                                 {"tokens": jnp.asarray(ds.batch_at(i))})
        losses.append(float(mets["loss"]))
    assert losses[-1] < losses[0] - 0.15, losses[::6]


def test_microbatch_matches_full_batch_grads():
    """Gradient accumulation must average to the full-batch gradient."""
    from repro.train.train_step import make_loss_fn
    cfg = get_config("musicgen-large", smoke=True)
    run = RunConfig(attention_impl="dense", remat="none",
                    compute_dtype="float32")
    params = tfm.init_model(cfg, jax.random.PRNGKey(1))
    ds = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8)
    batch = {"tokens": jnp.asarray(ds.batch_at(0))}
    loss_fn = make_loss_fn(cfg, run)
    (_, _), g_full = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    n = 4
    micro = jax.tree.map(lambda x: x.reshape(n, -1, *x.shape[1:]), batch)
    g_acc = jax.tree.map(jnp.zeros_like, g_full)
    for i in range(n):
        mb = jax.tree.map(lambda x: x[i], micro)
        (_, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        g_acc = jax.tree.map(jnp.add, g_acc, g)
    g_acc = jax.tree.map(lambda x: x / n, g_acc)
    errs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), g_full, g_acc)
    assert max(jax.tree.leaves(errs)) < 1e-4, sorted(
        errs.items(), key=lambda kv: -kv[1])[:3]
