"""Graph input: Pajek/edge-list loader round trips + generator determinism.

Covers ``core/graph.py::load_pajek_or_edgelist`` and
``core/generators.py::paper_profile`` — the two untested data-entry
points the docs point real-cluster users at.
"""
import numpy as np
import pytest

from repro.core import brute_force_census, from_edges, load_pajek_or_edgelist
from repro.core.generators import PAPER_DATASETS, paper_profile
from repro.core.graph import dense_adjacency


def _write(tmp_path, name, text):
    p = tmp_path / name
    p.write_text(text)
    return str(p)


def test_pajek_round_trip(tmp_path):
    """Pajek *Vertices/*Arcs/*Edges (1-indexed, labeled vertex lines)
    reproduces the graph built directly with from_edges (0-indexed)."""
    path = _write(tmp_path, "g.net", """\
% a Pajek file, as exported by real SNA tools
*Vertices 6
1 "alice"
2 "bob"
3 "carol"
4 "dave"
5 "erin"
6 "frank"
*Arcs
1 2
2 3
3 1
*Edges
4 5
""")
    g = load_pajek_or_edgelist(path)
    # arcs are directed; each *Edges line materializes both directions
    want = from_edges(6, [0, 1, 2, 3, 4], [1, 2, 0, 4, 3], directed=True)
    assert (g.n, g.m, g.m_nbr) == (want.n, want.m, want.m_nbr) == (6, 5, 8)
    assert (dense_adjacency(g) == dense_adjacency(want)).all()
    assert (brute_force_census(g).counts
            == brute_force_census(want).counts).all()


def test_pajek_vertex_count_beats_max_id(tmp_path):
    """*Vertices pins n even when trailing vertices are isolated."""
    path = _write(tmp_path, "iso.net", "*Vertices 9\n*Arcs\n1 2\n")
    g = load_pajek_or_edgelist(path)
    assert g.n == 9 and g.m == 1


def test_plain_edgelist_zero_indexed(tmp_path):
    """Bare `u v` lines: 0-indexed, n inferred, comments/blanks skipped."""
    path = _write(tmp_path, "g.txt", """\
# comment
% other comment style

0 1
1 2
2 0
2 0
""")
    g = load_pajek_or_edgelist(path)
    want = from_edges(3, [0, 1, 2], [1, 2, 0])  # duplicate arc deduped
    assert (g.n, g.m) == (3, 3)
    assert (dense_adjacency(g) == dense_adjacency(want)).all()


def test_edgelist_census_matches_oracle(tmp_path):
    rng = np.random.default_rng(3)
    src, dst = rng.integers(0, 12, 30), rng.integers(0, 12, 30)
    lines = "\n".join(f"{u} {v}" for u, v in zip(src, dst))
    g = load_pajek_or_edgelist(_write(tmp_path, "r.txt", lines))
    want = from_edges(12, src, dst)
    assert (brute_force_census(g).counts
            == brute_force_census(want).counts).all()


def test_paper_profile_deterministic():
    """Same (name, scale_down, seed) -> bit-identical graph arrays."""
    a = paper_profile("slashdot", scale_down=2048.0, seed=7)
    b = paper_profile("slashdot", scale_down=2048.0, seed=7)
    assert (a.n, a.m, a.m_nbr, a.max_deg) == (b.n, b.m, b.m_nbr, b.max_deg)
    for f in ("out_ptr", "out_idx", "nbr_ptr", "nbr_idx", "nbr_deg"):
        assert (np.asarray(getattr(a.arrays, f))
                == np.asarray(getattr(b.arrays, f))).all()
    # a different seed is a different realization of the same profile
    c = paper_profile("slashdot", scale_down=2048.0, seed=8)
    assert c.n == a.n
    assert not (np.asarray(c.arrays.out_idx).tolist()
                == np.asarray(a.arrays.out_idx).tolist())


@pytest.mark.parametrize("name", sorted(PAPER_DATASETS))
def test_paper_profile_shapes(name):
    """Every Table 4.1 profile builds: pow2 vertex count >= 64, CSR
    invariants hold, and undirected datasets come out mutual."""
    g = paper_profile(name, scale_down=4096.0, seed=0)
    assert g.n >= 64 and (g.n & (g.n - 1)) == 0  # R-MAT: power of two
    ptr = np.asarray(g.arrays.out_ptr)
    assert ptr.shape == (g.n + 1,) and ptr[0] == 0 and ptr[-1] == g.m
    assert (np.diff(ptr) >= 0).all()
    nbr_ptr = np.asarray(g.arrays.nbr_ptr)
    assert nbr_ptr[-1] == g.m_nbr and g.m_nbr % 2 == 0
    if not PAPER_DATASETS[name][2]:  # undirected: every arc is mutual
        assert g.m_nbr == g.m
