"""Incremental delta census: GraphDelta normalization, affected-dyad
exactness, apply_delta == full recompute bit-identity for every
registered op on all three backends (static + dynamic schedules), the
one-sync-per-delta regression pin, the delta_threshold cost-model
fallback, subscribed-session serving, the plan-cache-bounded task memo,
and a forced-8-device subprocess driving the delta pass through the real
work-queue pool."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (GraphDelta, affected_dyads, apply_delta_csr,
                        brute_force_census, canonical_dyads, from_edges,
                        generators, load_pajek_or_edgelist)
from repro.engine import (EngineConfig, GraphOp, PlanShapeError,
                          clear_plan_cache, compile, plan_cache_stats,
                          register_op)
from repro.engine.ops import make_census_batch_fn, unregister_op
from repro.serve import CensusService, ServiceConfig

BACKENDS = ["xla", "pallas", "distributed"]
ALL_OPS = ("triad_census", "dyad_census", "degree_stats", "triadic_profile")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(autouse=True)
def _fresh_cache():
    clear_plan_cache()
    yield
    clear_plan_cache()


def _cfg(backend, **kw):
    kw.setdefault("batch", 16)
    kw.setdefault("chunk_dyads", 64)
    kw.setdefault("delta_threshold", 1.0)  # always exercise the delta path
    return EngineConfig(backend=backend, **kw)


def _arcs(g):
    out_ptr = np.asarray(g.arrays.out_ptr)[: g.n + 1]
    dst = np.asarray(g.arrays.out_idx)[: g.m].astype(np.int64)
    src = np.repeat(np.arange(g.n, dtype=np.int64), np.diff(out_ptr))
    return src, dst


def _rand_delta(g, rng, k_rem=3, k_add=3):
    src, dst = _arcs(g)
    rem = None
    if g.m and k_rem:
        sel = rng.choice(g.m, size=min(k_rem, g.m), replace=False)
        rem = np.stack([src[sel], dst[sel]], 1)
    add = rng.integers(0, g.n, size=(k_add, 2)) if k_add else None
    return GraphDelta(edges_added=add, edges_removed=rem)


def _assert_result_equal(got, want, ctx=""):
    assert type(got) is type(want), (ctx, got, want)
    for name, a, b in zip(type(got)._fields, got, want):
        if isinstance(a, np.ndarray):
            assert np.array_equal(a, b), (ctx, name, a, b)
        else:
            assert a == b, (ctx, name, a, b)


# ----------------------------------------------------------------------------
# GraphDelta normalization + validation (host layer)
# ----------------------------------------------------------------------------

def test_graph_delta_normalizes():
    d = GraphDelta(edges_added=[(1, 2), (2, 2), (1, 2), (3, 1)],
                   edges_removed=[(0, 1), (0, 1), (4, 4)])
    assert d.edges_added.shape == (2, 2)  # self-loop + duplicate dropped
    assert d.edges_removed.shape == (1, 2)
    assert d.size == 3 and not d.is_empty
    assert d.touched.tolist() == [0, 1, 2, 3]
    assert GraphDelta().is_empty and len(GraphDelta().touched) == 0


def test_graph_delta_rejects_bad_input():
    with pytest.raises(ValueError, match="edges_added"):
        GraphDelta(edges_added=[(1, 2, 3)])
    with pytest.raises(ValueError, match=">= 0"):
        GraphDelta(edges_removed=[(-1, 2)])
    g = from_edges(4, [0, 1], [1, 2])
    with pytest.raises(ValueError, match="n=4"):
        affected_dyads(g, GraphDelta(edges_added=[(0, 9)]))
    with pytest.raises(ValueError, match="n=4"):
        apply_delta_csr(g, GraphDelta(edges_removed=[(9, 0)]))


def test_apply_delta_csr_matches_rebuilt_graph():
    g = generators.rmat(5, edge_factor=4, seed=0)
    rng = np.random.default_rng(1)
    d = _rand_delta(g, rng, k_rem=4, k_add=4)
    g2 = apply_delta_csr(g, d)
    assert g2.n == g.n
    # oracle: mutate the arc list by hand and rebuild through from_edges
    src, dst = _arcs(g)
    key = src * g.n + dst
    rem = d.edges_removed[:, 0] * g.n + d.edges_removed[:, 1]
    keep = ~np.isin(key, rem)
    want = from_edges(g.n, np.concatenate([src[keep], d.edges_added[:, 0]]),
                      np.concatenate([dst[keep], d.edges_added[:, 1]]))
    for f in ("n", "m", "m_nbr", "max_deg", "max_out_deg"):
        assert getattr(g2, f) == getattr(want, f), f
    for name in ("out_ptr", "out_idx", "nbr_ptr", "nbr_idx", "nbr_deg"):
        assert np.array_equal(np.asarray(getattr(g2.arrays, name)),
                              np.asarray(getattr(want.arrays, name))), name
    # removing absent arcs / adding present ones is a no-op
    src2, dst2 = _arcs(g2)
    same = apply_delta_csr(g2, GraphDelta(
        edges_added=np.stack([src2[:3], dst2[:3]], 1),
        edges_removed=[(g.n - 1, g.n - 2)] if not (
            (src2 == g.n - 1) & (dst2 == g.n - 2)).any() else None))
    assert same.m == g2.m


def test_affected_dyads_are_touched_incident_and_sorted():
    g = generators.rmat(6, edge_factor=4, seed=2)
    d = GraphDelta(edges_added=[(3, 7)], edges_removed=[(10, 11)])
    u, v = affected_dyads(g, d)
    touched = set(d.touched.tolist())
    assert len(u) and (u < v).all()
    assert all(a in touched or b in touched for a, b in zip(u, v))
    # every canonical dyad incident to a touched vertex is present
    cu, cv = canonical_dyads(g)
    inc = [(a, b) for a, b in zip(cu.tolist(), cv.tolist())
           if a in touched or b in touched]
    assert sorted(zip(u.tolist(), v.tolist())) == sorted(inc)
    key = u.astype(np.int64) * g.n + v
    assert (np.diff(key) > 0).all()  # deterministic sorted order


# ----------------------------------------------------------------------------
# bit-identity: apply_delta == full recompute, every op, every backend
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("schedule", ["static", "dynamic"])
def test_apply_delta_bit_identical_to_full(backend, schedule):
    g = generators.rmat(6, edge_factor=4, seed=3)
    plan = compile(g, ALL_OPS, _cfg(backend, schedule=schedule))
    raw = plan.run_raw(g)
    rng = np.random.default_rng(7)
    cur = g
    for step in range(3):
        d = _rand_delta(cur, rng)
        res = plan.apply_delta(cur, d, raw)
        assert res.mode == "delta", (step, res.affected_fraction)
        full = plan.run_raw(res.graph)
        assert np.array_equal(res.raw, full), (backend, schedule, step)
        want = plan.layout.finalize(full, res.graph)
        for name in ALL_OPS:
            _assert_result_equal(res.results[name], want[name],
                                 (backend, schedule, step, name))
        # and the oracle agrees (not just internal consistency)
        _assert_result_equal(
            res.results["triad_census"], brute_force_census(res.graph),
            (backend, schedule, step))
        cur, raw = res.graph, res.raw


@pytest.mark.parametrize("backend", BACKENDS)
def test_delta_degenerate_cases(backend):
    g = generators.rmat(5, edge_factor=3, seed=4)
    plan = compile(g, ALL_OPS, _cfg(backend))
    raw = plan.run_raw(g)

    # empty delta: zero-cost identity, no sync, still mode "delta"
    syncs = plan.stats["host_syncs"]
    res = plan.apply_delta(g, GraphDelta(), raw)
    assert res.mode == "delta" and res.affected_fraction == 0.0
    assert res.raw is raw and plan.stats["host_syncs"] == syncs

    # delete-all: the correction must drive every bin to the empty graph's
    src, dst = _arcs(g)
    wipe = GraphDelta(edges_removed=np.stack([src, dst], 1))
    res = plan.apply_delta(g, wipe, raw)
    assert res.graph.m == 0 and res.graph.n_dyads == 0
    assert np.array_equal(res.raw, plan.run_raw(res.graph))
    assert res.results["triad_census"].counts.sum() == \
        brute_force_census(res.graph).counts.sum()

    # resurrect from empty: every dyad of the new graph is affected
    back = GraphDelta(edges_added=np.stack([src, dst], 1))
    res2 = plan.apply_delta(res.graph, back, res.raw)
    assert res2.mode == "delta" and res2.affected_fraction == 1.0
    assert np.array_equal(res2.raw, raw)  # round trip: original bins back

    # add-then-remove in separate applications is also an exact round trip
    probe = GraphDelta(edges_added=[(0, g.n - 1), (g.n - 1, 0)])
    mid = plan.apply_delta(g, probe, raw)
    final = plan.apply_delta(
        mid.graph, GraphDelta(edges_removed=probe.edges_added), mid.raw)
    assert np.array_equal(final.raw, raw)


def test_apply_delta_on_pajek_graph(tmp_path):
    p = tmp_path / "toy.net"
    p.write_text("*Vertices 12\n*Arcs\n" + "\n".join(
        f"{a} {b}" for a, b in [(1, 2), (2, 3), (3, 1), (4, 5), (5, 6),
                                (6, 4), (7, 8), (9, 10), (11, 12), (1, 7)]))
    g = load_pajek_or_edgelist(str(p))
    plan = compile(g, ALL_OPS, _cfg("xla"))
    raw = plan.run_raw(g)
    res = plan.apply_delta(g, GraphDelta(edges_added=[(0, 8), (8, 0)],
                                         edges_removed=[(0, 1)]), raw)
    assert res.mode == "delta"
    assert np.array_equal(res.raw, plan.run_raw(res.graph))
    _assert_result_equal(res.results["triad_census"],
                         brute_force_census(res.graph))


def test_random_mutation_sequence_stays_exact():
    """Deterministic long-stream soak: 12 mixed mutations, raw bins never
    drift from the full recompute (the invariant hypothesis fuzzes below)."""
    g = generators.rmat(6, edge_factor=3, seed=5)
    plan = compile(g, ALL_OPS, _cfg("xla"))
    raw = plan.run_raw(g)
    rng = np.random.default_rng(11)
    cur = g
    for step in range(12):
        d = _rand_delta(cur, rng, k_rem=int(rng.integers(0, 5)),
                        k_add=int(rng.integers(0, 5)))
        res = plan.apply_delta(cur, d, raw)
        cur, raw = res.graph, res.raw
    assert np.array_equal(raw, plan.run_raw(cur))


def test_property_random_mutations_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    g0 = generators.rmat(5, edge_factor=3, seed=6)
    plan = compile(g0, ALL_OPS, _cfg("xla"))
    base_raw = plan.run_raw(g0)
    edge = st.tuples(st.integers(0, g0.n - 1), st.integers(0, g0.n - 1))

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.tuples(st.lists(edge, max_size=4),
                              st.lists(edge, max_size=4)),
                    min_size=1, max_size=4))
    def prop(seq):
        cur, raw = g0, base_raw
        for add, rem in seq:
            res = plan.apply_delta(
                cur, GraphDelta(edges_added=add or None,
                                edges_removed=rem or None), raw)
            cur, raw = res.graph, res.raw
        assert np.array_equal(raw, plan.run_raw(cur))

    prop()


# ----------------------------------------------------------------------------
# sync accounting + cost-model fallback + opt-out
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_delta_costs_exactly_one_sync(backend):
    g = generators.rmat(6, edge_factor=4, seed=8)
    plan = compile(g, ALL_OPS, _cfg(backend))
    raw = plan.run_raw(g)
    d = _rand_delta(g, np.random.default_rng(0))
    before = plan.stats["host_syncs"]
    res = plan.apply_delta(g, d, raw)
    assert res.mode == "delta"
    assert plan.stats["host_syncs"] - before == 1, backend
    assert plan.stats["delta_runs"] == 1 and plan.stats["delta_fulls"] == 0


def test_delta_threshold_falls_back_to_full():
    g = generators.rmat(5, edge_factor=4, seed=9)
    plan = compile(g, ("triad_census",), _cfg("xla", delta_threshold=0.01))
    raw = plan.run_raw(g)
    d = _rand_delta(g, np.random.default_rng(1), k_rem=8, k_add=8)
    res = plan.apply_delta(g, d, raw)
    assert res.mode == "full" and res.affected_fraction > 0.01
    assert np.array_equal(res.raw, plan.run_raw(res.graph))
    assert plan.stats["delta_fulls"] == 1
    # raw=None also forces the full path regardless of footprint
    plan2 = compile(g, ("triad_census",), _cfg("xla"))
    res2 = plan2.apply_delta(g, GraphDelta(edges_added=[(0, 1)]))
    assert res2.mode == "full"
    assert np.array_equal(res2.raw, plan2.run_raw(res2.graph))


def test_sync_baseline_takes_full_path():
    g = generators.rmat(5, edge_factor=3, seed=10)
    plan = compile(g, ("triad_census",), _cfg("xla", device_accum=False))
    raw = plan.run_raw(g)
    res = plan.apply_delta(g, GraphDelta(edges_added=[(0, 1)]), raw)
    assert res.mode == "full"
    assert np.array_equal(res.raw, plan.run_raw(res.graph))


def test_non_local_op_forces_full_path():
    class NonLocal(GraphOp):
        name = "_nonlocal_probe"
        bins = 16
        kernel_key = "triad_census"  # reuse the census kernel/slice
        delta_local = False          # ...but claim a wider data horizon

        def make_batch_fn(self, meta, config):
            return make_census_batch_fn(meta.k, meta.member_iters,
                                        config.acc_jnp_dtype)

        def finalize(self, raw, g):
            return int(np.asarray(raw).sum())

    register_op(NonLocal())
    try:
        g = generators.rmat(5, edge_factor=3, seed=12)
        plan = compile(g, ("triad_census", "_nonlocal_probe"), _cfg("xla"))
        raw = plan.run_raw(g)
        res = plan.apply_delta(g, GraphDelta(edges_added=[(0, 2)]), raw)
        assert res.mode == "full"
        assert np.array_equal(res.raw, plan.run_raw(res.graph))
    finally:
        unregister_op("_nonlocal_probe")


def test_growth_past_buckets_raises_plan_shape_error():
    g = from_edges(16, [0, 1, 2], [1, 2, 3])
    plan = compile(g, ("triad_census",), _cfg("xla"))
    raw = plan.run_raw(g)
    hub = GraphDelta(edges_added=np.stack(
        [np.zeros(15, np.int64), np.arange(1, 16)], 1))
    with pytest.raises(PlanShapeError):
        plan.apply_delta(g, hub, raw)


def test_delta_threshold_validated():
    for bad in (0.0, -0.5, 1.5):
        with pytest.raises(ValueError, match="delta_threshold"):
            EngineConfig(delta_threshold=bad)
    assert EngineConfig(delta_threshold=1).delta_threshold == 1.0
    with pytest.raises(ValueError, match="max_sessions"):
        ServiceConfig(max_sessions=0)


# ----------------------------------------------------------------------------
# subscribed evolving-graph sessions (serve layer)
# ----------------------------------------------------------------------------

def _svc(**census_kw):
    return CensusService(ServiceConfig(
        census=_cfg("xla", **census_kw), max_sessions=2))


def test_session_mutate_poll_cycle():
    svc = _svc()
    g = generators.rmat(6, edge_factor=4, seed=13)
    sid = svc.subscribe(g, ops=("triad_census", "degree_stats"))
    rng = np.random.default_rng(2)
    for _ in range(3):
        ack = svc.mutate(sid, _rand_delta(svc._sessions[sid].graph, rng,
                                          k_rem=2, k_add=2))
        assert ack["mode"] == "delta"
    cur = svc._sessions[sid].graph
    res = svc.poll(sid)
    want = compile(cur, ("triad_census", "degree_stats"),
                   svc.config.census).run(cur)
    _assert_result_equal(res["triad_census"], want["triad_census"])
    _assert_result_equal(res["degree_stats"], want["degree_stats"])
    st = svc.stats()["sessions"][sid]
    assert st["mutations"] == 3 and st["deltas"] == 3 and st["fulls"] == 0
    # single-op sessions poll the bare result; unsubscribe frees the slot
    sid2 = svc.subscribe(cur)
    _assert_result_equal(svc.poll(sid2), brute_force_census(cur))
    final = svc.unsubscribe(sid2)
    _assert_result_equal(final, brute_force_census(cur))
    assert sid2 not in svc.stats()["sessions"]
    with pytest.raises(KeyError, match="unknown session"):
        svc.poll(sid2)


def test_session_limit_and_stateless_poll_coexist():
    svc = _svc()
    g = generators.rmat(5, edge_factor=3, seed=14)
    svc.subscribe(g)
    svc.subscribe(g)
    with pytest.raises(RuntimeError, match="max_sessions"):
        svc.subscribe(g)
    # the stateless request stream is unaffected by live sessions
    rid = svc.submit(g)
    done = svc.flush()
    assert [c.request_id for c in done] == [rid]
    assert svc.poll() == []  # no-arg poll keeps its drain semantics


def test_session_recompile_on_bucket_outgrowth():
    svc = _svc()
    g = from_edges(32, [0, 1, 2], [1, 2, 3])
    sid = svc.subscribe(g)
    hub = GraphDelta(edges_added=np.stack(
        [np.zeros(20, np.int64), np.arange(1, 21)], 1))
    ack = svc.mutate(sid, hub)
    assert ack["mode"] == "recompile" and ack["m"] == 22
    cur = svc._sessions[sid].graph
    _assert_result_equal(svc.poll(sid), brute_force_census(cur))
    # the recompiled session keeps taking deltas on its new plan
    ack2 = svc.mutate(sid, GraphDelta(edges_removed=[(0, 20)]))
    assert ack2["mode"] == "delta"
    cur = svc._sessions[sid].graph
    _assert_result_equal(svc.poll(sid), brute_force_census(cur))
    st = svc.stats()["sessions"][sid]
    assert st["recompiles"] == 1 and st["deltas"] == 1


# ----------------------------------------------------------------------------
# satellite: the task-memo's lifetime is tied to the plan cache
# ----------------------------------------------------------------------------

def test_task_memo_bounded_and_cleared_with_plan_cache():
    g = generators.rmat(6, edge_factor=4, seed=15)
    plan = compile(g, ("triad_census",), _cfg("pallas"))
    plan.run(g)
    assert len(plan._task_memo) == 1  # the host-derived bucket schedule
    entry = plan_cache_stats()["entries"][-1]
    assert entry["task_memo"] == 1
    # memo stays bounded across many distinct graphs (same bucket only)
    for s in range(10):
        gg = generators.rmat(6, edge_factor=4, seed=100 + s)
        if gg.max_deg > plan.meta.k:
            continue  # would need a recompile; irrelevant to the memo
        plan.run(gg)
    assert len(plan._task_memo) <= 8
    clear_plan_cache()
    assert len(plan._task_memo) == 0  # lifetime tied to the cache


# ----------------------------------------------------------------------------
# the real pool: delta pass under forced 8 host devices (subprocess — the
# flag must be set before jax initializes; mirrors test_executor.py)
# ----------------------------------------------------------------------------

def test_delta_under_forced_device_pool():
    code = """
import numpy as np, jax
assert len(jax.devices()) == 8
from repro.core import GraphDelta, generators
from repro.engine import EngineConfig, compile
g = generators.rmat(7, edge_factor=4, seed=16)
ops = ("triad_census", "dyad_census", "degree_stats", "triadic_profile")
for backend in ("xla", "pallas"):
    plan = compile(g, ops, EngineConfig(backend=backend, batch=16,
                                        chunk_dyads=64, schedule="dynamic",
                                        delta_threshold=1.0))
    raw = plan.run_raw(g)
    assert plan.executor.n_devices == 8
    rng = np.random.default_rng(0)
    add = rng.integers(0, g.n, size=(6, 2))
    res = plan.apply_delta(g, GraphDelta(edges_added=add), raw)
    assert res.mode == "delta", backend
    assert np.array_equal(res.raw, plan.run_raw(res.graph)), backend
print('OK')
"""
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": SRC}
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
