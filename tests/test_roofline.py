"""Roofline HLO walker: known-FLOPs modules and loop multipliers."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import roofline as R


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_dot_flops_counted():
    m, k, n = 256, 512, 128
    c = _compile(lambda a, b: a @ b,
                 jnp.zeros((m, k)), jnp.zeros((k, n)))
    acc = R.analyze_hlo(c.as_text())
    assert abs(acc["flops"] - 2 * m * k * n) / (2 * m * k * n) < 0.01


def test_scan_multiplies_flops():
    m = 128
    w = jnp.eye(m)

    def body(x, _):
        return x @ w, None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    c = _compile(f, jnp.zeros((m, m)))
    acc = R.analyze_hlo(c.as_text())
    want = 10 * 2 * m ** 3
    assert abs(acc["flops"] - want) / want < 0.05, acc["flops"]


def test_bytes_positive_and_bounded():
    x = jnp.zeros((1024, 1024))
    c = _compile(lambda a: (a * 2 + 1).sum(), x)
    acc = R.analyze_hlo(c.as_text())
    nbytes = 1024 * 1024 * 4
    assert nbytes * 0.5 <= acc["bytes"] <= nbytes * 6


def test_model_flops_formulae():
    meta = {"active_params": 1e9, "kind": "train", "global_batch": 4,
            "seq_len": 128}
    assert R.model_flops(meta) == 6e9 * 4 * 128
    meta["kind"] = "decode"
    assert R.model_flops(meta) == 2e9 * 4
    meta["kind"] = "prefill"
    assert R.model_flops(meta) == 2e9 * 4 * 128


def test_terms_and_bottleneck():
    t = R.roofline_terms(197e12, 819e9 * 2, 50e9 * 3)
    assert t["compute_s"] == 1.0
    assert t["memory_s"] == 2.0
    assert t["collective_s"] == 3.0
    assert t["bottleneck"] == "collective_s"
    assert t["step_s_lower_bound"] == 3.0
