"""Triad census correctness: vectorized algorithm vs brute-force oracle."""
import numpy as np
import pytest

from repro.core import (brute_force_census, canonical_dyads, from_edges,
                        triad_census)
from repro.core import generators
from repro.core.triad_table import CLASS_MULTIPLICITY, TRIAD_TABLE_64


def test_table_multiplicities():
    assert CLASS_MULTIPLICITY.tolist() == [1, 6, 3, 3, 3, 6, 6, 6, 6, 2, 3,
                                           3, 3, 6, 6, 1]
    assert TRIAD_TABLE_64[0] == 0  # empty -> 003
    assert TRIAD_TABLE_64[63] == 15  # complete -> 300


@pytest.mark.parametrize("seed", range(4))
def test_census_matches_brute_force_er(seed):
    g = generators.erdos_renyi(40, 150, seed=seed)
    assert (triad_census(g, batch=32).counts
            == brute_force_census(g).counts).all()


@pytest.mark.parametrize("seed", range(3))
def test_census_matches_brute_force_rmat(seed):
    g = generators.rmat(7, edge_factor=4, seed=seed)
    assert (triad_census(g, batch=64).counts
            == brute_force_census(g).counts).all()


def test_census_undirected_graph():
    # undirected (mutual-dyad) graphs: the Actors-network case
    rng = np.random.default_rng(0)
    src = rng.integers(0, 30, 100)
    dst = rng.integers(0, 30, 100)
    g = from_edges(30, src, dst, directed=False)
    got = triad_census(g).counts
    want = brute_force_census(g).counts
    assert (got == want).all()
    # an undirected graph has no asymmetric dyads: only 003/102/201/300
    asym_types = [1, 3, 4, 5, 6, 7, 8, 9, 11, 12, 13, 14]
    assert got[asym_types].sum() == 0


def test_census_total_closed_form():
    g = generators.rmat(6, edge_factor=8, seed=9)
    res = triad_census(g)
    assert res.total == g.n * (g.n - 1) * (g.n - 2) // 6


def test_empty_and_tiny_graphs():
    g = from_edges(5, [], [], directed=True)
    res = triad_census(g) if g.n_dyads else None
    # no dyads: census fn needs >=1 task; the closed form covers it
    assert g.n_dyads == 0
    g2 = from_edges(3, [0, 1], [1, 2])
    got = triad_census(g2).counts
    want = brute_force_census(g2).counts
    assert (got == want).all()
    assert got.sum() == 1  # exactly one triad


def test_canonical_dyads_count():
    g = generators.rmat(6, edge_factor=4, seed=2)
    u, v = canonical_dyads(g)
    assert (u < v).all()
    assert len(u) == g.n_dyads
