"""Fault tolerance: checkpoint atomicity, auto-resume, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import RunConfig, get_config
from repro.data import SyntheticTokens
from repro.models import transformer as tfm
from repro.train import CheckpointManager, adamw_init, make_train_step
from repro.train.elastic import StepWatchdog, plan_elastic_mesh, reshard_tree


def _tiny_state():
    cfg = get_config("musicgen-large", smoke=True)
    params = tfm.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params, adamw_init(params)


def test_roundtrip(tmp_path):
    cfg, params, opt = _tiny_state()
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(7, {"params": params, "m": opt.m, "v": opt.v},
             meta={"step": 7, "note": "x"})
    assert mgr.latest_step() == 7
    trees, meta = mgr.restore(7)
    assert meta["note"] == "x"
    for k in params:
        assert np.allclose(np.asarray(params[k]), np.asarray(trees["params"][k]))


def test_async_save_and_gc(tmp_path):
    cfg, params, opt = _tiny_state()
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"params": params})
    mgr.wait()
    assert mgr.all_steps() == [3, 4]  # gc keeps last 2


def test_crash_mid_save_leaves_latest_valid(tmp_path):
    """A tmp dir without MANIFEST must be ignored by auto-resume."""
    cfg, params, opt = _tiny_state()
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(5, {"params": params})
    # simulate a crash: a half-written directory
    crash = tmp_path / "step_0000000009"
    crash.mkdir()
    (crash / "params").mkdir()
    assert mgr.latest_step() == 5


def test_resume_training_reproduces_stream(tmp_path):
    """Kill/restart: resuming from step k replays the same data batches."""
    cfg, params, opt = _tiny_state()
    run = RunConfig(attention_impl="dense", remat="none", learning_rate=1e-3)
    step_fn = jax.jit(make_train_step(cfg, run))
    ds = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)
    mgr = CheckpointManager(str(tmp_path), async_save=False)

    # run 6 steps, checkpoint at 3
    p, o = params, opt
    for i in range(6):
        p, o, _ = step_fn(p, o, {"tokens": jnp.asarray(ds.batch_at(i))})
        if i == 2:
            mgr.save(3, {"params": p, "m": o.m, "v": o.v},
                     meta={"step": 3})
    # "restart": restore and continue 3..6
    trees, meta = mgr.restore(mgr.latest_step())
    from repro.train.optimizer import OptState
    p2 = trees["params"]
    o2 = OptState(step=jnp.int32(meta["step"]), m=trees["m"], v=trees["v"])
    for i in range(meta["step"], 6):
        p2, o2, _ = step_fn(p2, o2, {"tokens": jnp.asarray(ds.batch_at(i))})
    for k in p:
        np.testing.assert_allclose(np.asarray(p[k]), np.asarray(p2[k]),
                                   atol=1e-6)


def test_elastic_plan_and_reshard():
    assert plan_elastic_mesh(512) == (32, 16)
    assert plan_elastic_mesh(256) == (16, 16)
    assert plan_elastic_mesh(496) == (31, 16)  # lost one chip -> lose a row
    with pytest.raises(ValueError):
        plan_elastic_mesh(8)
    # reshard on the 1-device container: exercise the device_put path
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("model",))
    tree = {"a": jnp.ones((4, 4)), "b": jnp.zeros((2,))}
    specs = {"a": P(None, "model"), "b": P()}
    out = reshard_tree(tree, mesh, specs)
    assert (np.asarray(out["a"]) == 1).all()


def test_watchdog_flags_straggler():
    wd = StepWatchdog(factor=3.0)
    import time
    for i in range(10):
        wd.start()
        time.sleep(0.002)
        assert not wd.stop(i)
    wd.start()
    time.sleep(0.05)
    assert wd.stop(99)
    assert wd.stragglers and wd.stragglers[0][0] == 99
