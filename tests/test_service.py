"""Batched serving layer: run_batch bit-identity, batching policy,
out-of-order completion, per-bucket stats, cache-entry metadata."""
import numpy as np
import pytest

from repro.core import brute_force_census, from_edges, generators
from repro.engine import (CensusConfig, GraphMeta, clear_plan_cache,
                          compile_census, plan_cache_stats)
from repro.serve import CensusCompletion, CensusService, ServiceConfig

CFG = CensusConfig(backend="xla", batch=16, chunk_dyads=64)


@pytest.fixture(autouse=True)
def _fresh_cache():
    clear_plan_cache()
    yield
    clear_plan_cache()


def _same_bucket(make, n, k=None):
    """First n generated graphs sharing the modal GraphMeta bucket."""
    groups = {}
    for seed in range(8 * n):
        g = make(seed)
        groups.setdefault(GraphMeta.from_graph(g, k=k), []).append(g)
        best = max(groups.values(), key=len)
        if len(best) >= n:
            return best[:n]
    raise AssertionError("could not assemble a same-bucket fleet")


# ----------------------------------------------------------------------------
# CensusPlan.run_batch
# ----------------------------------------------------------------------------

def test_run_batch_bit_identical_to_sequential():
    """The acceptance criterion: B same-bucket graphs through run_batch
    == B sequential plan.run calls, bit for bit (and == the oracle)."""
    fleet = _same_bucket(
        lambda s: generators.rmat(6, edge_factor=4, seed=s), 5, k=CFG.k)
    plan = compile_census(fleet[0], CFG)
    batched = plan.run_batch(fleet)
    for got, g in zip(batched, fleet):
        want = plan.run(g)
        assert (got.counts == want.counts).all()
        assert got.counts.dtype == want.counts.dtype == np.int64
        assert (got.counts == brute_force_census(g).counts).all()
    assert plan.stats["batch_runs"] == 1
    assert plan.stats["batch_graphs"] == len(fleet)


def test_run_batch_b1_matches_run():
    g = generators.rmat(6, edge_factor=4, seed=0)
    plan = compile_census(g, CFG)
    assert (plan.run_batch([g])[0].counts == plan.run(g).counts).all()


def test_run_batch_mixed_sizes_same_bucket():
    """Graphs of different true size (same buckets) batch correctly,
    including a zero-dyad graph whose result is the closed form only."""
    g = generators.rmat(6, edge_factor=4, seed=0)
    empty = from_edges(5, [], [])
    tiny = from_edges(4, [0, 1], [1, 2])
    plan = compile_census(g, CFG)
    out = plan.run_batch([empty, g, tiny])
    assert out[0].counts[0] == 5 * 4 * 3 // 6
    assert out[0].counts[1:].sum() == 0
    assert (out[1].counts == plan.run(g).counts).all()
    assert (out[2].counts == brute_force_census(tiny).counts).all()


def test_run_batch_empty_list_and_admission():
    g = generators.rmat(6, edge_factor=2, seed=0)
    plan = compile_census(g, CFG)
    assert plan.run_batch([]) == []
    g_big = generators.rmat(9, edge_factor=8, seed=0)
    with pytest.raises(ValueError, match="recompile"):
        plan.run_batch([g, g_big])


def test_run_batch_one_transfer_per_batch():
    """B graphs, one device->host sync (the dispatch amortization)."""
    fleet = _same_bucket(
        lambda s: generators.rmat(6, edge_factor=4, seed=s), 4, k=CFG.k)
    plan = compile_census(fleet[0], CFG)
    s0 = plan.stats["host_syncs"]
    plan.run_batch(fleet)
    assert plan.stats["host_syncs"] == s0 + 1


@pytest.mark.parametrize("backend", ["pallas", "distributed"])
def test_run_batch_fallback_backends(backend):
    """Backends without a vmapped unit fall back member-wise — same
    results, same API."""
    g1 = generators.rmat(6, edge_factor=4, seed=0)
    g2 = generators.rmat(6, edge_factor=4, seed=1)
    plan = compile_census(g1, CensusConfig(backend=backend, batch=16,
                                           chunk_dyads=256))
    plan._check(g2)  # same bucket by construction of the seeds above
    out = plan.run_batch([g1, g2])
    assert (out[0].counts == brute_force_census(g1).counts).all()
    assert (out[1].counts == brute_force_census(g2).counts).all()


# ----------------------------------------------------------------------------
# CensusService batching policy
# ----------------------------------------------------------------------------

def test_service_results_match_oracle_and_ids_are_stable():
    svc = CensusService(ServiceConfig(max_batch=4, max_wait_requests=100,
                                      census=CFG))
    fleet = [generators.rmat(6, edge_factor=4, seed=s) for s in range(7)]
    ids = [svc.submit(g) for g in fleet]
    assert ids == list(range(7))
    done = {c.request_id: c.result for c in svc.flush()}
    assert sorted(done) == ids and svc.pending == 0
    for i, g in zip(ids, fleet):
        assert (done[i].counts == brute_force_census(g).counts).all()


def test_service_flushes_full_batches_eagerly():
    """A bucket group executes inside submit() as soon as it fills."""
    fleet = _same_bucket(
        lambda s: generators.rmat(6, edge_factor=4, seed=s), 4, k=CFG.k)
    svc = CensusService(ServiceConfig(max_batch=2, max_wait_requests=100,
                                      census=CFG))
    svc.submit(fleet[0])
    assert svc.pending == 1 and not svc.poll()
    svc.submit(fleet[1])  # fills the bucket -> runs now
    done = svc.poll()
    assert [c.request_id for c in done] == [0, 1]
    assert svc.pending == 0
    assert all(isinstance(c, CensusCompletion) for c in done)


def test_service_out_of_order_completion():
    """A late-arriving bucket can complete before an earlier request."""
    a = _same_bucket(
        lambda s: generators.rmat(6, edge_factor=4, seed=s), 2, k=CFG.k)
    b = from_edges(4, [0, 1], [1, 2])  # a different (tiny) bucket
    svc = CensusService(ServiceConfig(max_batch=2, max_wait_requests=100,
                                      census=CFG))
    svc.submit(b)          # rid 0, waits (bucket of one)
    svc.submit(a[0])       # rid 1
    svc.submit(a[1])       # rid 2 -> fills a's bucket, completes first
    assert [c.request_id for c in svc.poll()] == [1, 2]
    assert [c.request_id for c in svc.flush()] == [0]


def test_service_max_wait_requests_bounds_staleness():
    """A partial group is force-flushed once max_wait newer requests
    passed it — no bucket waits forever behind hot ones."""
    slow = from_edges(4, [0, 1], [1, 2])
    hot = _same_bucket(
        lambda s: generators.rmat(6, edge_factor=4, seed=s), 3, k=CFG.k)
    svc = CensusService(ServiceConfig(max_batch=100, max_wait_requests=2,
                                      census=CFG))
    rid = svc.submit(slow)
    svc.submit(hot[0])
    assert not [c for c in svc.poll() if c.request_id == rid]
    svc.submit(hot[1])  # 2 newer than rid -> next submit flushes it
    done = svc.poll()
    assert any(c.request_id == rid for c in done)


def test_service_hot_bucket_burst_fills_to_max_batch():
    """Staleness counts other-bucket arrivals only: a hot bucket's own
    burst is never force-flushed below max_batch."""
    hot = _same_bucket(
        lambda s: generators.rmat(6, edge_factor=4, seed=s), 4, k=CFG.k)
    svc = CensusService(ServiceConfig(max_batch=4, max_wait_requests=1,
                                      census=CFG))
    for g in hot[:3]:
        svc.submit(g)
        assert not svc.poll()  # still batching despite max_wait=1
    svc.submit(hot[3])  # fills max_batch -> one full-width batch
    assert len(svc.poll()) == 4
    meta = GraphMeta.from_graph(hot[0], k=CFG.k)
    assert svc.stats()["buckets"][meta]["occupancy"] == 1.0


def test_run_fleet_preserves_prior_pending_completions():
    """run_fleet must not swallow completions of requests submitted
    before it — they stay queued for the next poll()."""
    early = from_edges(4, [0, 1], [1, 2])
    svc = CensusService(ServiceConfig(max_batch=8, max_wait_requests=100,
                                      census=CFG))
    rid = svc.submit(early)
    fleet = [generators.rmat(6, edge_factor=4, seed=s) for s in range(3)]
    out = svc.run_fleet(fleet)
    assert len(out) == 3
    held = svc.poll()
    assert [c.request_id for c in held] == [rid]
    assert (held[0].result.counts == brute_force_census(early).counts).all()


def test_service_max_wait_zero_is_unbatched():
    svc = CensusService(ServiceConfig(max_batch=8, max_wait_requests=0,
                                      census=CFG))
    g = generators.rmat(6, edge_factor=4, seed=0)
    rid = svc.submit(g)
    done = svc.poll()
    assert [c.request_id for c in done] == [rid]  # flushed immediately


def test_service_stats_and_cache_entries():
    fleet = _same_bucket(
        lambda s: generators.rmat(6, edge_factor=4, seed=s), 4, k=CFG.k)
    svc = CensusService(ServiceConfig(max_batch=4, max_wait_requests=100,
                                      census=CFG))
    svc.run_fleet(fleet)
    st = svc.stats()
    assert st["requests"] == 4 and st["batches"] == 1
    assert st["mean_batch"] == 4.0
    meta = GraphMeta.from_graph(fleet[0], k=CFG.k)
    bucket = st["buckets"][meta]
    assert bucket["occupancy"] == 1.0
    assert bucket["host_syncs"] == 1  # one transfer served all 4 requests
    # plan_cache_stats carries the per-bucket entry metadata the service
    # (and dashboards) read: bucket fields + live counters.
    entries = plan_cache_stats()["entries"]
    assert len(entries) == 1
    e = entries[0]
    assert e["meta"]["n_bucket"] == meta.n_bucket
    assert e["backend"] == "xla" and e["batch_runs"] == 1
    assert e["runs"] == 4 and e["device_path"] is True


def test_service_config_validation():
    with pytest.raises(ValueError):
        ServiceConfig(max_batch=0)
    with pytest.raises(ValueError):
        ServiceConfig(max_wait_requests=-1)


def test_run_fleet_returns_input_order():
    svc = CensusService(ServiceConfig(max_batch=3, census=CFG))
    fleet = [generators.rmat(6, edge_factor=4, seed=s) for s in range(5)]
    out = svc.run_fleet(fleet)
    assert len(out) == 5
    for res, g in zip(out, fleet):
        assert (res.counts == brute_force_census(g).counts).all()
