"""Doc smoke: the docs can't rot.

Every fenced ```python block in README.md and docs/*.md is extracted and
executed (tiny graphs, interpret mode off-TPU), and the public engine /
serve API surface is checked for docstrings — including every
CensusConfig / ServiceConfig field being described in its class
docstring.
"""
import dataclasses
import inspect
import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOC_FILES = [ROOT / "README.md",
             *sorted((ROOT / "docs").glob("*.md"))]


def _python_blocks():
    params = []
    for path in DOC_FILES:
        text = path.read_text()
        for i, m in enumerate(re.finditer(r"```python\n(.*?)```", text,
                                          re.DOTALL)):
            params.append(pytest.param(path, m.group(1),
                                       id=f"{path.name}-{i}"))
    return params


def test_docs_exist_and_are_substantial():
    for required in ("docs/ARCHITECTURE.md", "docs/PAPER_MAPPING.md"):
        p = ROOT / required
        assert p.exists(), f"{required} is missing"
        assert len(p.read_text()) > 2000, f"{required} is a stub"
    # both docs must carry executable examples
    names = {p.name for p, _ in
             ((pp.values[0], pp.values[1]) for pp in _python_blocks())}
    assert {"README.md", "ARCHITECTURE.md", "PAPER_MAPPING.md"} <= names


@pytest.mark.parametrize("path,code", _python_blocks())
def test_doc_block_executes(path, code):
    """Each fenced python block is a self-contained runnable example."""
    exec(compile(code, f"{path.name}", "exec"), {"__name__": "__doc_smoke__"})


def _public_api():
    import repro.engine as engine
    import repro.serve as serve

    for mod in (engine, serve):
        for name in mod.__all__:
            yield mod.__name__, name, getattr(mod, name)


@pytest.mark.parametrize("mod,name,obj", [
    pytest.param(m, n, o, id=f"{m}.{n}") for m, n, o in _public_api()
    if inspect.isclass(o) or callable(o)])
def test_public_api_has_docstrings(mod, name, obj):
    doc = inspect.getdoc(obj)
    assert doc and len(doc.strip()) > 20, f"{mod}.{name} lacks a docstring"


@pytest.mark.parametrize("cls_path", ["repro.engine:CensusConfig",
                                      "repro.serve:ServiceConfig"])
def test_config_docstrings_cover_every_field(cls_path):
    """Every config knob is described in its class docstring — new fields
    can't land undocumented."""
    mod_name, cls_name = cls_path.split(":")
    import importlib
    cls = getattr(importlib.import_module(mod_name), cls_name)
    doc = inspect.getdoc(cls)
    for f in dataclasses.fields(cls):
        assert re.search(rf"\b{re.escape(f.name)}\b", doc), (
            f"{cls_name} docstring does not document field {f.name!r}")


def test_plan_public_methods_have_docstrings():
    from repro.engine import CensusPlan

    for name in ("run", "run_batch", "padded_arrays", "padded_arrays_host",
                 "aot_lower", "batch_fn"):
        doc = inspect.getdoc(getattr(CensusPlan, name))
        assert doc and len(doc.strip()) > 20, f"CensusPlan.{name}"
