"""Distributed census + cell lowering on forced multi-device meshes.

These run in subprocesses because the host-platform device-count flag must
be set before jax initializes (the main pytest process keeps 1 device).
"""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout=600):
    env = {**os.environ,
           "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
           "PYTHONPATH": SRC}
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


def test_distributed_census_multidevice():
    code = """
import jax, numpy as np
from repro.core import generators
from repro import core
g = generators.rmat(7, edge_factor=4, seed=11)
ref = core.brute_force_census(g)
mesh = jax.make_mesh((2, 4), ('data', 'model'))
for strat in ('greedy_sequential', 'sorted_snake'):
    got, tasks = core.distributed_triad_census(g, mesh, strategy=strat)
    assert (ref.counts == got.counts).all(), (strat, ref.counts, got.counts)
print('OK')
"""
    r = _run(code)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_distributed_census_multipod_axes():
    code = """
import jax, numpy as np
from repro.core import generators
from repro import core
g = generators.rmat(6, edge_factor=4, seed=3)
ref = core.brute_force_census(g)
mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
got, _ = core.distributed_triad_census(g, mesh)
assert (ref.counts == got.counts).all()
print('OK')
"""
    r = _run(code)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


@pytest.mark.parametrize("arch,shape", [
    ("qwen3-4b", "train_4k"),
    ("zamba2-1.2b", "long_500k"),
    ("granite-moe-3b-a800m", "decode_32k"),
])
def test_cell_lowers_and_compiles_small_mesh(arch, shape):
    """Full-size cells must lower+compile on a (2,2) stand-in mesh."""
    code = f"""
import jax
from repro.launch.specs import build_cell
mesh = jax.make_mesh((2, 2), ('data', 'model'))
cell = build_cell({arch!r}, {shape!r}, mesh)
with mesh:
    c = jax.jit(cell.step_fn, in_shardings=cell.in_shardings).lower(*cell.args).compile()
assert c.cost_analysis() is not None
print('OK')
"""
    r = _run(code, devices=4, timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_dryrun_records_exist_or_smoke_cell():
    """If the sweep has run, every produced record must be ok/skip."""
    d = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
    if not os.path.isdir(d):
        pytest.skip("sweep not run yet")
    bad = []
    for f in os.listdir(d):
        if f.endswith(".json"):
            rec = json.load(open(os.path.join(d, f)))
            if rec.get("status") not in ("ok", "skip"):
                bad.append((f, rec.get("error", rec.get("status"))))
    assert not bad, bad[:5]


def test_expert_parallel_a2a_moe():
    """shard_map expert-parallel MoE: exact vs reference, and its compiled
    collective profile is 2x all-to-all with ZERO all-reduce."""
    code = """
import jax, jax.numpy as jnp, dataclasses, re
from repro.config import get_config
from repro.models import moe, transformer as tfm
from repro.models.moe_expert_parallel import make_expert_parallel_moe

cfg = get_config('deepseek-v2-236b', smoke=True)
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, capacity_factor=64.0, n_shared_experts=0, d_ff_shared=0))
mesh = jax.make_mesh((2, 4), ('data', 'model'))
params = tfm.init_model(cfg, jax.random.PRNGKey(0))
sub = {k[len('layers/'):]: v[0] for k, v in params.items()
       if k.startswith('layers/')}
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
y_ref, _ = moe.moe_apply(cfg, sub, 'moe/', x)
ep_moe = make_expert_parallel_moe(cfg, mesh)
with mesh:
    fn = jax.jit(lambda p, xx: ep_moe(p, 'moe/', xx))
    y_ep = fn(sub, x)
    hlo = fn.lower(sub, x).compile().as_text()
assert float(jnp.abs(y_ref - y_ep).max()) < 1e-4
assert len(re.findall(r' all-to-all', hlo)) >= 2
assert len(re.findall(r' all-reduce', hlo)) == 0
print('OK')
"""
    r = _run(code, devices=8, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
