"""Engine front door: backend agreement, plan caching, streaming execution."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import brute_force_census, from_edges, generators
from repro.engine import (CensusConfig, GraphMeta, clear_plan_cache,
                          compile_census, plan_cache_stats)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(autouse=True)
def _fresh_cache():
    clear_plan_cache()
    yield
    clear_plan_cache()


@pytest.mark.parametrize("backend", ["xla", "pallas", "distributed"])
@pytest.mark.parametrize("seed", range(3))
def test_backends_match_brute_force(backend, seed):
    g = generators.rmat(6, edge_factor=4, seed=seed)
    want = brute_force_census(g).counts
    cfg = CensusConfig(backend=backend, batch=32, chunk_dyads=256)
    got = compile_census(g, cfg).run(g).counts
    assert (got == want).all(), (backend, got, want)


@pytest.mark.parametrize("backend", ["xla", "pallas", "distributed"])
def test_backends_match_on_random_digraphs(backend):
    rng = np.random.default_rng(7)
    for trial in range(4):
        n = int(rng.integers(8, 28))
        m = int(rng.integers(n, 4 * n))
        g = from_edges(n, rng.integers(0, n, m), rng.integers(0, n, m))
        if g.n_dyads == 0:
            continue
        want = brute_force_census(g).counts
        cfg = CensusConfig(backend=backend, batch=16, chunk_dyads=64)
        got = compile_census(g, cfg).run(g).counts
        assert (got == want).all(), (backend, n, m, got, want)


def test_auto_backend_resolves_and_runs():
    g = generators.rmat(6, edge_factor=4, seed=0)
    plan = compile_census(g, CensusConfig(backend="auto"))
    assert plan.backend in ("xla", "pallas", "distributed")
    assert (plan.run(g).counts == brute_force_census(g).counts).all()


def test_plan_cache_same_shape_hits_no_retrace():
    """Second census on a same-shape graph: identical plan, zero retraces."""
    cfg = CensusConfig(backend="xla", batch=32, chunk_dyads=128)
    g1 = generators.rmat(6, edge_factor=4, seed=1)
    p1 = compile_census(g1, cfg)
    assert (p1.run(g1).counts == brute_force_census(g1).counts).all()
    traces = p1.stats["traces"]
    assert traces >= 1

    g2 = generators.rmat(6, edge_factor=4, seed=9)  # same metadata buckets
    assert GraphMeta.from_graph(g2) == GraphMeta.from_graph(g1)
    p2 = compile_census(g2, cfg)
    assert p2 is p1  # cache hit returns the identical plan object
    assert (p2.run(g2).counts == brute_force_census(g2).counts).all()
    assert p1.stats["traces"] == traces  # no retrace on the warm path
    stats = plan_cache_stats()
    assert stats["hits"] == 1 and stats["misses"] == 1


def test_plan_cache_fresh_plan_on_shape_change():
    cfg = CensusConfig(backend="xla", batch=32)
    g_small = generators.rmat(6, edge_factor=4, seed=0)
    g_big = generators.rmat(8, edge_factor=8, seed=0)
    p1 = compile_census(g_small, cfg)
    p2 = compile_census(g_big, cfg)
    assert p2 is not p1
    assert plan_cache_stats()["misses"] == 2
    # and a config change is also a fresh plan
    p3 = compile_census(g_small, CensusConfig(backend="xla", batch=64))
    assert p3 is not p1


@pytest.mark.parametrize("backend", ["xla", "pallas", "distributed"])
def test_chunked_streaming_matches_single_shot(backend):
    g = generators.rmat(7, edge_factor=4, seed=3)
    single = compile_census(
        g, CensusConfig(backend=backend, batch=16, chunk_dyads=10**6))
    chunked = compile_census(
        g, CensusConfig(backend=backend, batch=16, chunk_dyads=48))
    res_single = single.run(g)
    res_chunked = chunked.run(g)
    assert (res_single.counts == res_chunked.counts).all()
    assert chunked.stats["chunks"] > single.stats["chunks"]


def test_plan_rejects_oversized_graph():
    g_small = generators.rmat(6, edge_factor=2, seed=0)
    g_big = generators.rmat(9, edge_factor=8, seed=0)
    plan = compile_census(g_small, CensusConfig(backend="xla"))
    with pytest.raises(ValueError, match="recompile"):
        plan.run(g_big)


def test_empty_graph_closed_form_only():
    g = from_edges(5, [], [])
    plan = compile_census(g, CensusConfig(backend="xla"))
    res = plan.run(g)
    assert res.counts[0] == 5 * 4 * 3 // 6
    assert res.counts[1:].sum() == 0


def test_xla_plan_aot_lowers():
    g = generators.rmat(6, edge_factor=4, seed=0)
    plan = compile_census(g, CensusConfig(backend="xla", batch=32))
    compiled = plan.aot_lower(g).compile()
    assert compiled.cost_analysis() is not None


def test_engine_distributed_multidevice_subprocess():
    """Engine's distributed backend on a forced 8-device host mesh."""
    code = """
import numpy as np
from repro.core import brute_force_census, generators
from repro.engine import CensusConfig, compile_census
g = generators.rmat(6, edge_factor=4, seed=11)
ref = brute_force_census(g).counts
plan = compile_census(g, CensusConfig(backend="distributed", batch=16,
                                      chunk_dyads=128))
import math
assert math.prod(plan.mesh.devices.shape) == 8
got = plan.run(g).counts
assert (ref == got).all(), (ref, got)
print('OK')
"""
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": SRC}
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
