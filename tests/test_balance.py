"""Load-balancing strategies (paper Table 4.8 reproduction)."""
import numpy as np
import pytest

from repro.core import (canonical_dyads, dyad_weights, exact_s_sizes,
                        pack_tasks)
from repro.core import generators


@pytest.fixture(scope="module")
def g():
    return generators.rmat(7, edge_factor=8, seed=1)


def test_exact_s_device_matches_host(g):
    u, v = canonical_dyads(g)
    assert (exact_s_sizes(g, u, v) == exact_s_sizes(g, u, v, device=False)).all()


def test_pack_partitions_exactly(g):
    """Every canonical dyad appears in exactly one shard (no loss, no dup)."""
    u, v = canonical_dyads(g)
    all_keys = set(zip(u.tolist(), v.tolist()))
    for strat in ("greedy_sequential", "sorted_snake", "greedy_lpt"):
        t = pack_tasks(g, 8, strategy=strat)
        got = [(int(a), int(b))
               for a, b, m in zip(t.u.ravel(), t.v.ravel(), t.valid.ravel())
               if m]
        assert len(got) == len(all_keys), strat
        assert set(got) == all_keys, strat


def test_snake_beats_paper_greedy(g):
    """Beyond-paper claim: sorted snake balances at least as well as the
    paper's sequential queue fill under the same weight model."""
    seq = pack_tasks(g, 16, strategy="greedy_sequential")
    snake = pack_tasks(g, 16, strategy="sorted_snake")
    lpt = pack_tasks(g, 16, strategy="greedy_lpt")
    assert snake.imbalance <= seq.imbalance + 1e-9
    assert lpt.imbalance <= snake.imbalance + 1e-6


def test_uniform_weight_is_paper_formula(g):
    u, v = canonical_dyads(g)
    deg = np.asarray(g.arrays.nbr_deg)
    w = dyad_weights(g, u, v, "canonical_uniform")
    assert (w == (deg[u] + deg[v] - 2)).all()


def test_nonuniform_weight_is_exact_s(g):
    u, v = canonical_dyads(g)
    w = dyad_weights(g, u, v, "canonical_nonuniform")
    assert (w == exact_s_sizes(g, u, v)).all()


def test_s_identity(g):
    """|S| = deg(u) + deg(v) - |N(u) ∩ N(v)| - 2 (set identity check)."""
    u, v = canonical_dyads(g)
    deg = np.asarray(g.arrays.nbr_deg)
    s = exact_s_sizes(g, u, v)
    assert (s <= deg[u] + deg[v] - 2).all()
    assert (s >= np.maximum(deg[u], deg[v]) - 2).all()


def test_pad_multiple(g):
    t = pack_tasks(g, 4, pad_multiple=256)
    assert t.u.shape[1] % 256 == 0
