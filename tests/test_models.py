"""Per-architecture smoke tests (assignment: reduced config, one
forward/train step on CPU, output shapes + no NaNs) and decode
consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import RunConfig, get_config, list_configs
from repro.models import transformer as tfm
from repro.train import adamw_init, make_train_step

RUN = RunConfig(attention_impl="chunked_causal", attention_chunk=16,
                remat="full")


def _inputs(cfg, B=2, T=32, key=jax.random.PRNGKey(0)):
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    prefix = None
    if cfg.n_prefix_embeds:
        prefix = jax.random.normal(key, (B, cfg.n_prefix_embeds, cfg.d_model),
                                   jnp.bfloat16)
    return toks, pos, prefix


@pytest.mark.parametrize("arch", list_configs())
def test_smoke_forward(arch):
    cfg = get_config(arch, smoke=True)
    params = tfm.init_model(cfg, jax.random.PRNGKey(0))
    fwd = tfm.make_forward(cfg, RUN)
    toks, pos, prefix = _inputs(cfg)
    logits, _, aux = jax.jit(
        lambda p, t, q: fwd(p, t, q, prefix_embeds=prefix))(params, toks, pos)
    T_exp = toks.shape[1] + (cfg.n_prefix_embeds or 0)
    assert logits.shape == (2, T_exp, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, dtype=np.float32)).any()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", list_configs())
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = tfm.init_model(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, RUN))
    B, T = 2, 32
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (B, T + 1), 0, cfg.vocab_size)}
    if cfg.n_prefix_embeds:
        batch["prefix_embeds"] = jax.random.normal(
            key, (B, cfg.n_prefix_embeds, cfg.d_model), jnp.bfloat16)
    params2, opt2, mets = step(params, opt, batch)
    assert np.isfinite(float(mets["loss"]))
    assert int(opt2.step) == 1
    # params actually changed
    diffs = [float(jnp.abs(params[k] - params2[k]).max()) for k in params]
    assert max(diffs) > 0


@pytest.mark.parametrize("arch", list_configs())
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    params = tfm.init_model(cfg, jax.random.PRNGKey(0))
    from repro.serve import make_serve_step
    serve = jax.jit(make_serve_step(cfg, RUN),
                    static_argnames=())
    cache = tfm.init_cache(cfg, 2, 64)
    toks = jnp.zeros((2, 1), jnp.int32)
    nxt, cache2, logits = serve(params, cache, toks, jnp.int32(0))
    assert nxt.shape == (2, 1)
    assert logits.shape == (2, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any()


@pytest.mark.parametrize("arch", ["qwen3-4b", "rwkv6-3b", "zamba2-1.2b",
                                  "deepseek-v2-236b", "h2o-danube-3-4b"])
def test_decode_matches_full_forward_f32(arch):
    import dataclasses
    cfg = get_config(arch, smoke=True)
    if cfg.moe is not None:
        # capacity-based MoE drops tokens when a micro-batch overloads an
        # expert; for an exact decode==forward check give ample capacity.
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    run = RunConfig(attention_impl="chunked_causal", attention_chunk=8,
                    remat="none", compute_dtype="float32")
    params = tfm.init_model(cfg, jax.random.PRNGKey(1))
    fwd = tfm.make_forward(cfg, run)
    B, T = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0,
                              cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    full, _, _ = jax.jit(lambda p, t, q: fwd(p, t, q))(params, toks, pos)
    cache = jax.tree.map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x,
        tfm.init_cache(cfg, B, max(T, cfg.sliding_window or 0)))
    step = jax.jit(lambda p, t, q, c, cp: fwd(p, t, q, cache=c, cache_pos=cp))
    outs = []
    for t in range(T):
        l, cache, _ = step(params, toks[:, t:t + 1], pos[:, t:t + 1], cache, t)
        outs.append(l[:, 0])
    err = float(jnp.abs(full - jnp.stack(outs, 1)).max())
    assert err < 1e-3, err
