"""MoE dispatch variants: flat vs grouped vs dense-eval equivalence,
capacity semantics, and position assignment invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.models import moe
from repro.models import transformer as tfm


def _setup(arch="granite-moe-3b-a800m", cf=16.0):
    cfg = get_config(arch, smoke=True)
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=cf))
    params = tfm.init_model(cfg, jax.random.PRNGKey(0))
    sub = {k[len("layers/"):]: v[0] for k, v in params.items()
           if k.startswith("layers/")}
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
    return cfg, sub, x


@pytest.mark.parametrize("arch", ["granite-moe-3b-a800m", "deepseek-v2-236b"])
def test_grouped_equals_flat_with_ample_capacity(arch):
    cfg, p, x = _setup(arch)
    y1, a1 = moe.moe_apply(cfg, p, "moe/", x)
    for G in (2, 4, 8):
        y2, a2 = moe.moe_apply(cfg, p, "moe/", x, groups=G)
        assert float(jnp.abs(y1 - y2).max()) < 1e-4, G
        assert abs(float(a1 - a2)) < 1e-6


def test_dense_eval_equals_dispatch():
    cfg, p, x = _setup()
    y1, _ = moe.moe_apply(cfg, p, "moe/", x)
    y2, _ = moe.moe_apply(cfg, p, "moe/", x, dense_eval=True)
    assert float(jnp.abs(y1 - y2).max()) < 1e-4


def test_capacity_drops_are_graceful():
    """With capacity_factor ~0, everything drops; output = shared experts
    only (granite has none -> zeros), never NaN."""
    cfg, p, x = _setup(cf=1e-6)
    y, aux = moe.moe_apply(cfg, p, "moe/", x)
    assert np.isfinite(np.asarray(y, np.float32)).all()
    assert np.isfinite(float(aux))


def test_positions_in_expert_invariants():
    ids = jnp.asarray(np.random.default_rng(0).integers(0, 5, 64), jnp.int32)
    pos = moe._positions_in_expert(ids, 5)
    ids_np, pos_np = np.asarray(ids), np.asarray(pos)
    for e in range(5):
        got = np.sort(pos_np[ids_np == e])
        # each expert's slots are 0..count-1, each exactly once
        assert (got == np.arange(len(got))).all(), (e, got)


def test_grouped_positions_local():
    """Group routing must not leak positions across groups."""
    cfg, p, x = _setup()
    # every token routes somewhere; with G groups, per-group capacity
    # suffices for its own tokens only
    y, _ = moe.moe_apply(cfg, p, "moe/", x, groups=4)
    assert y.shape == x.shape
