"""GraphOp layer: per-op NumPy parity, fused == per-op bit-identity across
backends, single-pass sync counts, cache unification between the census
wrapper and the new API, config validation, registry pluggability, and
mixed-analytic serving."""
import numpy as np
import pytest

from repro.core import brute_force_census, from_edges, generators
from repro.core.graph import load_pajek_or_edgelist
from repro.engine import (CensusConfig, EngineConfig, GraphOp, clear_plan_cache,
                          compile, compile_census, get_op, list_ops,
                          plan_cache_stats, register_op)
from repro.engine.ops import unregister_op
from repro.serve import CensusService, ServiceConfig

ALL_OPS = ("triad_census", "dyad_census", "degree_stats", "triadic_profile")
BACKENDS = ["xla", "pallas", "distributed"]
CFG = EngineConfig(backend="xla", batch=16, chunk_dyads=64)


@pytest.fixture(autouse=True)
def _fresh_cache():
    clear_plan_cache()
    yield
    clear_plan_cache()


def _assert_result_equal(got, want, ctx=""):
    """Field-exact equality for op result NamedTuples (arrays included)."""
    assert type(got) is type(want), (ctx, got, want)
    for name, a, b in zip(type(got)._fields, got, want):
        if isinstance(a, np.ndarray):
            assert np.array_equal(a, b), (ctx, name, a, b)
        else:
            assert a == b, (ctx, name, a, b)


def _pajek_graph(tmp_path):
    """A small real-format graph through the Pajek loader (directed arcs +
    undirected edges, 1-indexed)."""
    text = """*Vertices 7
1 "a"
2 "b"
3 "c"
4 "d"
5 "e"
6 "f"
7 "g"
*Arcs
1 2
2 3
3 1
4 5
5 4
*Edges
6 7
1 4
"""
    p = tmp_path / "toy.net"
    p.write_text(text)
    return load_pajek_or_edgelist(str(p))


def _graphs(tmp_path):
    rng = np.random.default_rng(3)
    n, m = 20, 60
    return [
        ("rmat", generators.rmat(6, edge_factor=4, seed=0)),
        ("random", from_edges(n, rng.integers(0, n, m),
                              rng.integers(0, n, m))),
        ("star", from_edges(9, [0] * 8, list(range(1, 9)))),
        ("tiny", from_edges(4, [0, 1], [1, 2])),
        ("empty", from_edges(5, [], [])),
        ("pajek", _pajek_graph(tmp_path)),
    ]


# ----------------------------------------------------------------------------
# per-op NumPy parity (satellite: each op validated against its reference)
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("op_name", ALL_OPS)
def test_op_matches_numpy_reference(op_name, tmp_path):
    """Every built-in op reproduces its NumPy oracle on generated + real
    (Pajek-loaded) + degenerate graphs."""
    op = get_op(op_name)
    for gname, g in _graphs(tmp_path):
        got = compile(g, (op_name,), CFG).run(g)[op_name]
        _assert_result_equal(got, op.reference(g), ctx=(op_name, gname))


def test_references_are_self_consistent():
    g = generators.rmat(6, edge_factor=4, seed=1)
    dy = get_op("dyad_census").reference(g)
    assert dy.mutual + dy.asymmetric + dy.null == g.n * (g.n - 1) // 2
    assert dy.mutual + dy.asymmetric == g.n_dyads  # connected pairs
    ds = get_op("degree_stats").reference(g)
    assert ds.out_hist.sum() == ds.in_hist.sum() == g.n
    assert ds.mean_out == ds.mean_in == g.m / g.n
    tp = get_op("triadic_profile").reference(g)
    assert 0.0 <= tp.transitivity <= 1.0
    # triangles/wedges consistent with the census bins they derive from
    census = brute_force_census(g).counts
    conn = [int(nm[0]) + int(nm[1])
            for nm in __import__("repro.core.triad_table",
                                 fromlist=["TRIAD_NAMES"]).TRIAD_NAMES]
    assert tp.triangles == sum(int(c) for c, k in zip(census, conn) if k == 3)


def test_triadic_profile_known_values():
    # directed 3-cycle -> one triangle, transitivity 1
    tri = compile(from_edges(3, [0, 1, 2], [1, 2, 0]),
                  ("triadic_profile",), CFG)
    p = tri.run(from_edges(3, [0, 1, 2], [1, 2, 0]))["triadic_profile"]
    assert p == (1, 0, 1.0, 1.0)
    # path 0-1-2 -> one open wedge, no triangle
    path = from_edges(3, [0, 1], [1, 2])
    p = compile(path, ("triadic_profile",), CFG).run(path)["triadic_profile"]
    assert p.triangles == 0 and p.open_triples == 1 and p.transitivity == 0.0


# ----------------------------------------------------------------------------
# fused == per-op passes, across backends (satellite: bit-identity)
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_pass_bit_identical_to_per_op_passes(backend):
    """The tentpole claim: one fused pass over the dyad stream produces
    exactly what N separate passes produce, on every backend."""
    g = generators.rmat(6, edge_factor=4, seed=2)
    cfg = EngineConfig(backend=backend, batch=16, chunk_dyads=64)
    fused = compile(g, ALL_OPS, cfg).run(g)
    assert tuple(fused) == ALL_OPS  # result dict preserves op order
    for name in ALL_OPS:
        solo = compile(g, (name,), cfg).run(g)[name]
        _assert_result_equal(solo, fused[name], ctx=(backend, name))
    assert (fused["triad_census"].counts == brute_force_census(g).counts).all()


@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_device_path_matches_sync_baseline(backend):
    g = generators.rmat(7, edge_factor=4, seed=3)
    cfg = dict(backend=backend, batch=16, chunk_dyads=64)
    dev = compile(g, ALL_OPS, EngineConfig(**cfg))
    syn = compile(g, ALL_OPS, EngineConfig(**cfg, device_accum=False))
    a, b = dev.run(g), syn.run(g)
    for name in ALL_OPS:
        _assert_result_equal(a[name], b[name], ctx=(backend, name))


def test_pallas_noncensus_plan_skips_tile_machinery():
    """A pallas plan with no census-kernel op must not pay the tile
    kernel's support system: no bucket sort, no transpose CSR — results
    still match the references (and, like every device path, exactly
    one sync)."""
    g = generators.rmat(6, edge_factor=4, seed=0)
    cfg = EngineConfig(backend="pallas", batch=16, chunk_dyads=64)
    plan = compile(g, ("dyad_census", "degree_stats"), cfg)
    res = plan.run(g)
    assert plan.stats["host_syncs"] == 1
    arrays = plan.padded_arrays(g)
    assert arrays.in_ptr is None  # transpose CSR skipped
    for name in ("dyad_census", "degree_stats"):
        _assert_result_equal(res[name], get_op(name).reference(g), ctx=name)


def test_fused_pass_single_sync_and_traversal():
    """Acceptance criterion: the 3-op fused plan costs exactly the same
    host syncs and chunk schedule as a census-only run — the extra
    analytics ride the same traversal."""
    g = generators.rmat(7, edge_factor=4, seed=1)
    solo = compile(g, ["triad_census"], EngineConfig(backend="xla",
                                                     chunk_dyads=64))
    fused = compile(g, ["triad_census", "dyad_census", "degree_stats"],
                    EngineConfig(backend="xla", chunk_dyads=64))
    solo.run(g)
    fused.run(g)
    assert fused.stats["host_syncs"] == solo.stats["host_syncs"] == 1
    assert fused.stats["chunks"] == solo.stats["chunks"] > 1


def test_fused_run_batch_bit_identical():
    """Vmapped multi-op batches == sequential multi-op runs, including a
    zero-dyad member whose results are pure closed form."""
    fleet = [generators.rmat(6, edge_factor=4, seed=s) for s in (0, 1)]
    empty = from_edges(5, [], [])
    plan = compile(fleet[0], ALL_OPS, CFG)
    s0 = plan.stats["host_syncs"]
    batched = plan.run_batch(fleet + [empty])
    assert plan.stats["host_syncs"] == s0 + 1  # one transfer for the batch
    for got, g in zip(batched, fleet + [empty]):
        want = plan.run(g)
        for name in ALL_OPS:
            _assert_result_equal(got[name], want[name], ctx=name)
    assert batched[2]["dyad_census"].null == 10
    assert batched[2]["degree_stats"].out_hist[0] == 5


def test_shared_kernel_key_single_slice():
    """triadic_profile shares the census kernel: fusing it with
    triad_census adds zero accumulator width."""
    g = generators.rmat(6, edge_factor=4, seed=0)
    both = compile(g, ("triad_census", "triadic_profile"), CFG)
    solo = compile(g, ("triad_census",), CFG)
    assert both.layout.total_bins == solo.layout.total_bins == 16


# ----------------------------------------------------------------------------
# cache unification (satellite: wrapper + new API share one entry)
# ----------------------------------------------------------------------------

def test_wrapper_and_new_api_share_one_cache_entry():
    g = generators.rmat(6, edge_factor=4, seed=0)
    wrapper = compile_census(g, CFG)
    plan = compile(g, ("triad_census",), CFG)
    st = plan_cache_stats()
    assert st["misses"] == 1 and st["hits"] == 1 and st["size"] == 1
    assert wrapper.stats is plan.stats  # same underlying compiled plan
    assert compile_census(g, CFG) is wrapper  # view identity holds
    assert (wrapper.run(g).counts
            == plan.run(g)["triad_census"].counts).all()
    entry = plan_cache_stats()["entries"][0]
    assert entry["ops"] == ("triad_census",)
    assert entry["runs"] == 2


def test_distinct_ops_are_distinct_plans():
    g = generators.rmat(6, edge_factor=4, seed=0)
    a = compile(g, ("triad_census",), CFG)
    b = compile(g, ("triad_census", "dyad_census"), CFG)
    assert a is not b and plan_cache_stats()["misses"] == 2
    # order matters for the result dict, so it is part of the key
    c = compile(g, ("dyad_census", "triad_census"), CFG)
    assert c is not b


# ----------------------------------------------------------------------------
# config validation (satellite: buckets)
# ----------------------------------------------------------------------------

def test_buckets_validated_at_construction():
    with pytest.raises(ValueError, match="non-empty"):
        EngineConfig(buckets=())
    with pytest.raises(ValueError, match="positive"):
        EngineConfig(buckets=(0, 32))
    with pytest.raises(ValueError, match="positive"):
        EngineConfig(buckets=(-4,))
    with pytest.raises(ValueError, match="strictly increasing"):
        EngineConfig(buckets=(128, 32))
    with pytest.raises(ValueError, match="strictly increasing"):
        EngineConfig(buckets=(32, 32, 128))
    # list input is normalized to a hashable tuple
    cfg = EngineConfig(buckets=[16, 64])
    assert cfg.buckets == (16, 64)
    hash(cfg)
    assert CensusConfig is EngineConfig  # the census-era alias


# ----------------------------------------------------------------------------
# registry pluggability
# ----------------------------------------------------------------------------

def test_custom_op_plugs_into_fused_pass():
    """A user-defined op registers by name and fuses with the built-ins —
    the API seam later scenarios plug into."""
    import jax.numpy as jnp

    class EdgeCountOp(GraphOp):
        """Counts connected dyads (undirected edges) from the stream."""

        name = "edge_count_test"
        bins = 1

        def make_batch_fn(self, meta, config):
            def fn(arrays, n, u, v, valid):
                return valid.sum(dtype=config.acc_jnp_dtype)[None]
            return fn

        def finalize(self, raw, g):
            return int(raw[0])

        def reference(self, g):
            return g.n_dyads

    register_op(EdgeCountOp())
    try:
        assert "edge_count_test" in list_ops()
        g = generators.rmat(6, edge_factor=4, seed=0)
        plan = compile(g, ("triad_census", "edge_count_test"), CFG)
        res = plan.run(g)
        assert res["edge_count_test"] == g.n_dyads
        assert (res["triad_census"].counts
                == brute_force_census(g).counts).all()
        with pytest.raises(ValueError, match="already registered"):
            register_op(EdgeCountOp())
    finally:
        unregister_op("edge_count_test")
    with pytest.raises(KeyError, match="edge_count_test"):
        get_op("edge_count_test")


def test_reregistered_op_gets_fresh_plan():
    """The cache keys on op instances: overwriting a registration must
    compile a fresh plan, never serve one built against the old kernel."""
    import jax.numpy as jnp

    class ConstOp(GraphOp):
        """Adds a fixed per-batch constant (distinguishes kernel vintages)."""

        name = "const_test"
        bins = 1

        def __init__(self, value):
            self.value = value

        def make_batch_fn(self, meta, config):
            val = self.value

            def fn(arrays, n, u, v, valid):
                return jnp.full((1,), val, config.acc_jnp_dtype)
            return fn

        def finalize(self, raw, g):
            return int(raw[0])

    g = generators.rmat(6, edge_factor=4, seed=0)
    register_op(ConstOp(1))
    try:
        p1 = compile(g, ("const_test",), CFG)
        v1 = p1.run(g)["const_test"]
        register_op(ConstOp(2), overwrite=True)
        p2 = compile(g, ("const_test",), CFG)
        assert p2 is not p1  # fresh plan, not the stale cached one
        assert p2.run(g)["const_test"] == 2 * v1
    finally:
        unregister_op("const_test")


def test_kernel_key_sharers_validated():
    """A rider op must match its kernel owner's bins, and the key's
    namesake owns the kernel regardless of op order."""
    g = generators.rmat(6, edge_factor=4, seed=0)

    class BadRider(GraphOp):
        """Mis-sized rider on the census kernel."""

        name = "bad_rider_test"
        kernel_key = "triad_census"
        bins = 1

    with pytest.raises(ValueError, match="bins=1 != 16"):
        compile(g, (BadRider(), "triad_census"), CFG)
    # rider listed first must not displace the namesake's kernel
    res = compile(g, ("triadic_profile", "triad_census"), CFG).run(g)
    assert (res["triad_census"].counts == brute_force_census(g).counts).all()


def test_ops_spec_validation():
    g = generators.rmat(6, edge_factor=4, seed=0)
    with pytest.raises(KeyError, match="unknown GraphOp"):
        compile(g, ("no_such_op",), CFG)
    with pytest.raises(ValueError, match="duplicate"):
        compile(g, ("dyad_census", "dyad_census"), CFG)
    with pytest.raises(ValueError, match="at least one"):
        compile(g, (), CFG)


# ----------------------------------------------------------------------------
# mixed-analytic serving
# ----------------------------------------------------------------------------

def test_service_batches_by_bucket_and_ops():
    """Same-bucket graphs with different ops form separate groups; each
    group rides one fused batch; single-op requests complete with bare
    results, multi-op requests with dicts."""
    fleet = [generators.rmat(6, edge_factor=4, seed=s) for s in range(4)]
    svc = CensusService(ServiceConfig(max_batch=2, max_wait_requests=100,
                                      census=CFG))
    r_census = svc.submit(fleet[0])                      # census-only group
    r_multi = svc.submit(fleet[1], ops=("triad_census", "degree_stats"))
    assert svc.pending == 2 and not svc.poll()           # two partial groups
    svc.submit(fleet[2])                                 # fills census group
    done = {c.request_id: c for c in svc.poll()}
    assert set(done) == {r_census, 2}
    assert done[r_census].ops == ("triad_census",)
    assert (done[r_census].result.counts
            == brute_force_census(fleet[0]).counts).all()
    svc.submit(fleet[3], ops=("triad_census", "degree_stats"))
    done = {c.request_id: c for c in svc.poll()}
    assert set(done) == {r_multi, 3}
    multi = done[r_multi]
    assert multi.ops == ("triad_census", "degree_stats")
    assert set(multi.result) == {"triad_census", "degree_stats"}
    _assert_result_equal(multi.result["degree_stats"],
                         get_op("degree_stats").reference(fleet[1]))
    st = svc.stats()
    meta = list(st["buckets"])[0]
    assert st["buckets"][meta]["by_ops"] == {
        ("triad_census",): 2, ("triad_census", "degree_stats"): 2}


def test_service_rejects_bad_ops_at_submit():
    """A bad ops spec fails the one submit, immediately — it must never
    queue and later take down its whole batch group at flush time."""
    svc = CensusService(ServiceConfig(max_batch=4, census=CFG))
    g = generators.rmat(6, edge_factor=4, seed=0)
    rid = svc.submit(g)  # a healthy pending request
    with pytest.raises(KeyError, match="unknown GraphOp"):
        svc.submit(g, ops=("dyad_censu",))  # typo
    assert svc.pending == 1  # the healthy request is untouched

    class Impostor(GraphOp):
        """Name-collides with the built-in census but is NOT registered —
        the service must refuse rather than silently run the built-in."""

        name = "triad_census"
        bins = 16

    with pytest.raises(ValueError, match="not the registered"):
        svc.submit(g, ops=(Impostor(),))
    svc.submit(g, ops=(get_op("dyad_census"),))  # registered instance: OK
    assert svc.pending == 2
    done = svc.flush()
    assert rid in [c.request_id for c in done]


def test_service_single_noncensus_op_bare_result():
    svc = CensusService(ServiceConfig(max_batch=1, census=CFG))
    g = generators.rmat(6, edge_factor=4, seed=0)
    svc.submit(g, ops="dyad_census")
    (c,) = svc.poll()
    _assert_result_equal(c.result, get_op("dyad_census").reference(g))


def test_run_fleet_with_ops():
    svc = CensusService(ServiceConfig(max_batch=4, census=CFG))
    fleet = [generators.rmat(6, edge_factor=4, seed=s) for s in range(3)]
    out = svc.run_fleet(fleet, ops=("dyad_census", "triadic_profile"))
    assert len(out) == 3
    for res, g in zip(out, fleet):
        _assert_result_equal(res["dyad_census"],
                             get_op("dyad_census").reference(g))
        _assert_result_equal(res["triadic_profile"],
                             get_op("triadic_profile").reference(g))
