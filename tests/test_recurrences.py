"""Chunked SSD / RWKV formulations vs exact per-step recurrences."""
import jax
import jax.numpy as jnp
import pytest

from repro.models import rwkv, ssm


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_matches_naive(chunk):
    key = jax.random.PRNGKey(0)
    B, T, H, P, N = 2, 32, 4, 8, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, T, N))
    Cm = jax.random.normal(ks[4], (B, T, N))

    S = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(T):
        da = jnp.exp(dt[:, t] * a)
        S = S * da[..., None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dt[:, t], Bm[:, t], x[:, t])
        ys.append(jnp.einsum("bn,bhpn->bhp", Cm[:, t], S))
    y_ref = jnp.stack(ys, 1)
    y, S_fin = ssm.ssd_chunked(x, dt, a, Bm, Cm, chunk=chunk)
    assert float(jnp.abs(y - y_ref).max()) < 1e-4
    assert float(jnp.abs(S - S_fin).max()) < 1e-4


def test_ssd_chunked_state_carry():
    """Running two half-sequences with carried state == one full pass."""
    key = jax.random.PRNGKey(1)
    B, T, H, P, N = 1, 32, 2, 4, 8
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, T, N))
    Cm = jax.random.normal(ks[4], (B, T, N))
    y_full, S_full = ssm.ssd_chunked(x, dt, a, Bm, Cm, chunk=8)
    h = T // 2
    y1, S1 = ssm.ssd_chunked(x[:, :h], dt[:, :h], a, Bm[:, :h], Cm[:, :h], 8)
    y2, S2 = ssm.ssd_chunked(x[:, h:], dt[:, h:], a, Bm[:, h:], Cm[:, h:], 8,
                             state0=S1)
    assert float(jnp.abs(jnp.concatenate([y1, y2], 1) - y_full).max()) < 1e-4
    assert float(jnp.abs(S2 - S_full).max()) < 1e-4


@pytest.mark.parametrize("chunk", [8, 16])
def test_wkv_chunked_matches_recurrent(chunk):
    key = jax.random.PRNGKey(2)
    B, T, H, D = 2, 32, 3, 8
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, T, H, D))
    v = jax.random.normal(ks[2], (B, T, H, D))
    w_log = -jnp.exp(jax.random.normal(ks[3], (B, T, H, D)) * 0.5)
    u = jax.random.normal(ks[4], (H, D)) * 0.1
    o_ref, S_ref = rwkv.wkv_recurrent(r, k, v, w_log, u)
    o, S = rwkv.wkv_chunked(r, k, v, w_log, u, chunk=chunk)
    assert float(jnp.abs(o - o_ref).max()) < 1e-3
    assert float(jnp.abs(S - S_ref).max()) < 1e-3


def test_wkv_extreme_decay_stable():
    """Clamped chunked path must stay finite under saturating decays."""
    B, T, H, D = 1, 64, 2, 8
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 4)
    r = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, T, H, D))
    v = jax.random.normal(ks[2], (B, T, H, D))
    w_log = jnp.full((B, T, H, D), -50.0)  # near-total forgetting
    u = jnp.zeros((H, D))
    o, S = rwkv.wkv_chunked(r, k, v, w_log, u, chunk=16)
    assert jnp.isfinite(o).all() and jnp.isfinite(S).all()
    o_ref, _ = rwkv.wkv_recurrent(r, k, v, w_log, u)
    assert float(jnp.abs(o - o_ref).max()) < 1e-3
