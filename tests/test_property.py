"""Property-based tests (hypothesis) for the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis not installed; property tests skipped")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (brute_force_census, from_edges, pack_tasks,
                        triad_census)
from repro.core.census import canonical_dyads
from repro.data import SyntheticTokens


def _graph_strategy(max_n=24, max_m=80):
    return st.integers(6, max_n).flatmap(
        lambda n: st.tuples(
            st.just(n),
            st.lists(st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
                     min_size=1, max_size=max_m)))


@settings(max_examples=25, deadline=None)
@given(_graph_strategy())
def test_census_equals_brute_force(data):
    n, edges = data
    src = [e[0] for e in edges]
    dst = [e[1] for e in edges]
    g = from_edges(n, src, dst)
    if g.n_dyads == 0:
        return
    assert (triad_census(g, batch=16).counts
            == brute_force_census(g).counts).all()


@settings(max_examples=15, deadline=None)
@given(_graph_strategy(), st.integers(0, 10_000))
def test_census_is_isomorphism_invariant(data, perm_seed):
    """Relabeling vertices must not change the census."""
    n, edges = data
    src = np.array([e[0] for e in edges])
    dst = np.array([e[1] for e in edges])
    g = from_edges(n, src, dst)
    if g.n_dyads == 0:
        return
    perm = np.random.default_rng(perm_seed).permutation(n)
    g2 = from_edges(n, perm[src], perm[dst])
    assert (triad_census(g, batch=16).counts
            == triad_census(g2, batch=16).counts).all()


@settings(max_examples=15, deadline=None)
@given(_graph_strategy())
def test_isolated_vertex_adds_only_null_and_dyadic(data):
    """Appending an isolated vertex adds exactly C(n,2) triads, all of
    which contain it and are null or dyadic."""
    n, edges = data
    src = [e[0] for e in edges]
    dst = [e[1] for e in edges]
    g = from_edges(n, src, dst)
    g2 = from_edges(n + 1, src, dst)
    if g.n_dyads == 0:
        return
    c1 = triad_census(g, batch=16).counts
    c2 = triad_census(g2, batch=16).counts
    # connected-triad classes (types 4..16, idx 3..15) must be unchanged
    assert (c1[3:] == c2[3:]).all()
    assert c2.sum() - c1.sum() == n * (n - 1) // 2


@settings(max_examples=15, deadline=None)
@given(_graph_strategy(), st.integers(2, 7))
def test_pack_tasks_exact_partition(data, n_shards):
    n, edges = data
    g = from_edges(n, [e[0] for e in edges], [e[1] for e in edges])
    if g.n_dyads == 0:
        return
    u, v = canonical_dyads(g)
    want = sorted(zip(u.tolist(), v.tolist()))
    for strat in ("greedy_sequential", "sorted_snake", "greedy_lpt"):
        t = pack_tasks(g, n_shards, strategy=strat)
        got = sorted((int(a), int(b)) for a, b, m in
                     zip(t.u.ravel(), t.v.ravel(), t.valid.ravel()) if m)
        assert got == want


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 4))
def test_data_pipeline_deterministic_and_sharded(seed, n_shards):
    gb = 8
    full = SyntheticTokens(vocab_size=97, seq_len=16, global_batch=gb,
                           seed=seed)
    b0 = full.batch_at(3)
    b1 = SyntheticTokens(vocab_size=97, seq_len=16, global_batch=gb,
                         seed=seed).batch_at(3)
    assert (b0 == b1).all()
    assert b0.max() < 97 and b0.min() >= 0
