"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode — kernel bodies execute in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import brute_force_census, generators, triad_census
from repro.kernels import ops, ref
from repro.kernels.triad_census import SENTINEL, census_tiles_pallas


@pytest.mark.parametrize("seed,block,buckets", [
    (0, 16, (16, 64)),
    (1, 32, (32,)),
    (2, 8, (8, 32, 128)),
])
def test_census_kernel_matches_brute_force(seed, block, buckets):
    g = generators.rmat(6, edge_factor=4, seed=seed)
    want = brute_force_census(g).counts
    got = ops.triad_census_kernel(g, block=block, buckets=buckets)
    assert (got == want).all(), (got, want)


def test_census_kernel_matches_tile_oracle():
    """Kernel vs ref.census_tiles_ref on identical random tiles."""
    g = generators.erdos_renyi(60, 240, seed=3)
    from repro.core.census import canonical_dyads
    u, v = canonical_dyads(g)
    D = (len(u) // 16) * 16
    u, v = u[:D].astype(np.int32), v[:D].astype(np.int32)
    K = max(g.max_deg, g.max_out_deg)
    tiles = ops.build_tiles(g, u.astype(np.int64), v.astype(np.int64), K)
    args = [jnp.asarray(tiles[k]) for k in
            ("out_u", "in_u", "out_v", "in_v", "nbr_u", "nbr_v")]
    want = ref.census_tiles_ref(*args, jnp.asarray(u), jnp.asarray(v), g.n)
    # oracle takes (out_u, in_u, ... , u, v, n) in different arg order
    got = census_tiles_pallas(jnp.asarray(u), jnp.asarray(v), g.n, *args,
                              block=16)
    assert (np.asarray(got) == np.asarray(want)).all()


@pytest.mark.parametrize("B,T,H,Hkv,D,chunk,win,dtype", [
    (2, 128, 4, 2, 64, 64, None, jnp.float32),
    (1, 256, 8, 8, 32, 128, None, jnp.float32),
    (2, 128, 4, 4, 64, 32, 48, jnp.float32),
    (1, 128, 4, 1, 128, 64, None, jnp.float32),
    (2, 64, 2, 2, 64, 64, None, jnp.bfloat16),
])
def test_flash_attention_vs_oracle(B, T, H, Hkv, D, chunk, win, dtype):
    key = jax.random.PRNGKey(B * T + H)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, T, H, D), dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, D), dtype)
    qp = jnp.broadcast_to(jnp.arange(T)[None], (B, T)).astype(jnp.int32)
    want = ref.flash_attention_ref(q, k, v, qp, qp, window=win)
    got = ops.flash_attention(q, k, v, qp, qp, window=win, chunk=chunk)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    assert float(jnp.abs(want.astype(jnp.float32)
                         - got.astype(jnp.float32)).max()) < tol


def test_flash_attention_matches_model_chunked_path():
    """Pallas kernel == the XLA chunked_causal twin used in the models."""
    from repro.models.attention import _chunked_attention
    key = jax.random.PRNGKey(7)
    B, T, H, Hkv, D = 2, 128, 4, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, T, Hkv, D))
    v = jax.random.normal(ks[2], (B, T, Hkv, D))
    qp = jnp.broadcast_to(jnp.arange(T)[None], (B, T)).astype(jnp.int32)
    xla = _chunked_attention(q, k, v, qp, qp, None, 64, triangular=True)
    pls = ops.flash_attention(q, k, v, qp, qp, chunk=64)
    assert float(jnp.abs(xla - pls).max()) < 2e-5
