"""Partitioned-graph subsystem: owned-dyad cuts and halo construction,
bit-identity of partitioned runs across partitions × backend × schedule
(one-sync pinned), star-graph halo coverage, partition × delta × fault ×
reorder cross composition, mmap/spill out-of-core budget, config knob
validation, partition metadata in plan_cache_stats / service stats, the
sharding.rules deprecation shim, and a forced-8-device subprocess."""
import importlib
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import brute_force_census, generators
from repro.core.delta import GraphDelta
from repro.core.graph import (arcs_host, arcs_host_iter, from_edges,
                              from_edges_mmap)
from repro.core.partition import (build_local_arrays, partition_cuts,
                                  partition_graph, shard_dyads)
from repro.core.census import canonical_dyads
from repro.engine import (EngineConfig, FaultPlan, clear_plan_cache,
                          compile, list_ops, plan_cache_stats)
from repro.serve import CensusService, ServiceConfig

BACKENDS = ["xla", "pallas", "distributed"]
ALL_OPS = tuple(list_ops())
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(autouse=True)
def _fresh_cache():
    clear_plan_cache()
    yield
    clear_plan_cache()


def _graph(seed=0, n=48, m=300):
    rng = np.random.default_rng(seed)
    return from_edges(n, rng.integers(0, n, m), rng.integers(0, n, m))


# ----------------------------------------------------------------------------
# host-side layout: cuts, owned dyads, local CSR
# ----------------------------------------------------------------------------

def test_partition_cuts_cover_and_balance():
    g = _graph(3)
    for parts in (1, 2, 4, 8):
        cuts = partition_cuts(g, parts)
        assert cuts[0] == 0 and cuts[-1] == g.n
        assert (np.diff(cuts) >= 0).all()
        assert len(cuts) == parts + 1
        total = sum(len(shard_dyads(g, int(a), int(b))[0])
                    for a, b in zip(cuts[:-1], cuts[1:]))
        assert total == g.n_dyads


def test_shard_dyads_concat_is_canonical_stream():
    g = _graph(4)
    cuts = partition_cuts(g, 4)
    us, vs = zip(*(shard_dyads(g, int(a), int(b))
                   for a, b in zip(cuts[:-1], cuts[1:])))
    u, v = np.concatenate(us), np.concatenate(vs)
    cu, cv = canonical_dyads(g)
    assert np.array_equal(u, cu) and np.array_equal(v, cv)


def test_local_arrays_keep_rows_bit_identical():
    g = _graph(5)
    part = partition_graph(g, 4)
    out_ptr = np.asarray(g.arrays.out_ptr)
    out_idx = np.asarray(g.arrays.out_idx)
    for s in part.shards:
        local = build_local_arrays(g, s.lo, s.hi, s.halo)
        kept = np.union1d(np.arange(s.lo, s.hi), s.halo).astype(int)
        for w in kept:
            row = out_idx[out_ptr[w]:out_ptr[w + 1]]
            lrow = local.out_idx[local.out_ptr[w]:local.out_ptr[w + 1]]
            assert np.array_equal(row, lrow), (s.index, w)
        # non-kept rows are empty — probes of them always miss
        absent = np.setdiff1d(np.arange(g.n), kept)
        assert (local.out_ptr[absent + 1] == local.out_ptr[absent]).all()
        assert int(local.out_ptr[-1]) == s.m_out
        assert int(local.nbr_ptr[-1]) == s.m_nbr


def test_star_graph_hub_row_is_every_remote_shards_halo():
    # hub 0 with spokes 1..n-1: every dyad involves the hub, so every
    # shard that doesn't own vertex 0 must carry its row as halo.
    n = 33
    spokes = np.arange(1, n)
    g = from_edges(n, np.zeros(n - 1, dtype=int), spokes)
    part = partition_graph(g, 4)
    for s in part.shards:
        if s.n_dyads and not (s.lo <= 0 < s.hi):
            assert 0 in s.halo, s
    base = compile(g, ALL_OPS, EngineConfig(backend="xla")).run_raw(g)
    plan = compile(g, ALL_OPS, EngineConfig(backend="xla", partitions=4))
    assert np.array_equal(plan.run_raw(g), base)


# ----------------------------------------------------------------------------
# bit-identity: partitions × backend × schedule, one sync pinned
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("schedule", ["static", "dynamic"])
def test_partitioned_bit_identity_every_op(backend, schedule):
    g = _graph(7, n=40, m=240)
    want = brute_force_census(g).counts
    base = compile(g, ALL_OPS, EngineConfig(backend="xla")).run_raw(g)
    for parts in (1, 2, 4, 8):
        cfg = EngineConfig(backend=backend, schedule=schedule,
                           partitions=parts, batch=64, chunk_dyads=64)
        plan = compile(g, ALL_OPS, cfg)
        s0 = plan.stats["host_syncs"]
        raw = plan.run_raw(g)
        # regression pin: a partitioned run is still ONE device→host sync
        assert plan.stats["host_syncs"] - s0 == 1, (backend, parts)
        assert np.array_equal(raw, base), (backend, schedule, parts)
        res = plan.run(g)
        assert (res["triad_census"].counts == want).all()
        if parts > 1:
            ps = plan.stats["partition"]
            assert ps["partitions"] == min(parts, g.n)
            assert sum(ps["shard_dyads"]) == g.n_dyads
            assert len(ps["halo_sizes"]) == ps["partitions"]


def test_partitioned_spill_bit_identity():
    g = _graph(9)
    base = compile(g, ALL_OPS, EngineConfig(backend="xla")).run_raw(g)
    for backend in BACKENDS:
        cfg = EngineConfig(backend=backend, partitions=4, spill=True)
        plan = compile(g, ALL_OPS, cfg)
        assert np.array_equal(plan.run_raw(g), base), backend
        assert plan.stats["partition"]["spill"] is True


def test_partitioned_empty_and_tiny_graphs():
    empty = from_edges(5, np.array([], int), np.array([], int))
    single = from_edges(4, np.array([0]), np.array([1]))
    for g in (empty, single):
        base = compile(g, ALL_OPS, EngineConfig(backend="xla")).run_raw(g)
        plan = compile(g, ALL_OPS, EngineConfig(backend="xla", partitions=8))
        assert np.array_equal(plan.run_raw(g), base)


def test_run_batch_partitioned_falls_back_memberwise():
    gs = [_graph(s, n=32, m=160) for s in range(3)]
    base = compile(gs[0], ALL_OPS, EngineConfig(backend="xla"))
    plan = compile(gs[0], ALL_OPS, EngineConfig(backend="xla", partitions=2))
    outs = plan.run_batch(gs)
    for g, out in zip(gs, outs):
        want = base.run(g)
        assert (out["triad_census"].counts
                == want["triad_census"].counts).all()


# ----------------------------------------------------------------------------
# out-of-core: mmap graph + spilled dyad staging under a budget
# ----------------------------------------------------------------------------

def test_mmap_graph_matches_device_graph(tmp_path):
    rng = np.random.default_rng(11)
    n, m = 64, 500
    src, dst = rng.integers(0, n, m), rng.integers(0, n, m)
    g = from_edges(n, src, dst)
    gm = from_edges_mmap(n, src, dst, dir=str(tmp_path))
    assert (gm.n, gm.m, gm.m_nbr) == (g.n, g.m, g.m_nbr)
    assert isinstance(gm.arrays.nbr_idx, np.ndarray)  # host-resident
    for a, b in zip(g.arrays[:5], gm.arrays[:5]):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    s1, d1 = arcs_host(g)
    s2 = np.concatenate([s for s, _ in arcs_host_iter(gm, block=13)])
    d2 = np.concatenate([d for _, d in arcs_host_iter(gm, block=13)])
    assert np.array_equal(s1, s2) and np.array_equal(d1, d2)
    cuts = partition_cuts(gm, 4)
    s3 = np.concatenate([s for s, _ in arcs_host_iter(gm, cuts=cuts)])
    assert np.array_equal(s1, s3)


def test_spill_run_completes_under_capped_staging_budget(tmp_path):
    # a dyad stream whose total staging exceeds an artificial budget:
    # the per-shard staging peak must stay under the cap while the full
    # stream (which a single-device run would materialize) exceeds it.
    g = generators.rmat(9, edge_factor=8, seed=2)  # n=512, ~4k arcs
    gm = from_edges_mmap(g.n, *arcs_host(g))
    base = compile(g, ("triad_census",),
                   EngineConfig(backend="xla")).run_raw(g)
    cfg = EngineConfig(backend="xla", partitions=8, spill=str(tmp_path),
                       batch=32, chunk_dyads=32)
    plan = compile(gm, ("triad_census",), cfg)
    raw = plan.run_raw(gm)
    assert np.array_equal(raw, base)
    ps = plan.stats["partition"]
    cap = ps["stream_bytes"] // 2  # the artificial in-memory budget
    assert ps["max_stage_bytes"] <= cap < ps["stream_bytes"], ps
    assert not os.listdir(str(tmp_path))  # scratch removed after the run


# ----------------------------------------------------------------------------
# cross composition: delta × fault recovery × reorder on partitioned plans
# ----------------------------------------------------------------------------

def test_partition_delta_touches_only_owner_shards():
    g = _graph(13, n=64, m=380)
    plan = compile(g, ALL_OPS, EngineConfig(backend="xla", partitions=8,
                                            delta_threshold=1.0))
    raw = plan.run_raw(g)
    delta = GraphDelta(edges_added=np.array([[1, 2]]))
    s0 = plan.stats["host_syncs"]
    res = plan.apply_delta(g, delta, raw)
    assert res.mode == "delta"
    assert plan.stats["host_syncs"] - s0 == 1  # the correction's one sync
    touched = plan.stats["partition"]["delta_shards"]
    assert 1 <= touched < plan.partitions
    want = compile(res.graph, ALL_OPS,
                   EngineConfig(backend="xla")).run_raw(res.graph)
    assert np.array_equal(res.raw, want)


@pytest.mark.parametrize("backend", BACKENDS)
def test_partition_delta_stream_matches_full(backend):
    g = _graph(17, n=40, m=220)
    cfg = EngineConfig(backend=backend, partitions=4, delta_threshold=1.0)
    plan = compile(g, ALL_OPS, cfg)
    raw = plan.run_raw(g)
    rng = np.random.default_rng(5)
    for step in range(3):
        delta = GraphDelta(
            edges_added=rng.integers(0, g.n, (3, 2)),
            edges_removed=rng.integers(0, g.n, (2, 2)))
        res = plan.apply_delta(g, delta, raw)
        g, raw = res.graph, res.raw
        want = compile(g, ALL_OPS, EngineConfig(backend="xla")).run_raw(g)
        assert np.array_equal(raw, want), (backend, step, res.mode)


def test_partition_fault_recovery_bit_identical():
    g = _graph(19)
    base = compile(g, ALL_OPS, EngineConfig(backend="xla")).run_raw(g)
    fp = FaultPlan(seed=7, chunk_failure_rate=0.3, fail_attempts=1)
    for schedule in ("static", "dynamic"):
        cfg = EngineConfig(backend="xla", partitions=4, schedule=schedule,
                           batch=32, chunk_dyads=32, fault_plan=fp)
        plan = compile(g, ALL_OPS, cfg)
        s0 = plan.stats["host_syncs"]
        raw = plan.run_raw(g)
        assert np.array_equal(raw, base), schedule
        assert plan.stats["host_syncs"] - s0 == 1
        assert plan.stats["faults"]["retries"] > 0  # faults actually fired


def test_partition_runtime_fault_demotes_whole_partitioned_run():
    g = _graph(21)
    base = compile(g, ALL_OPS, EngineConfig(backend="xla")).run_raw(g)
    fp = FaultPlan(seed=3, runtime_failure=("pallas",))
    plan = compile(g, ALL_OPS, EngineConfig(backend="pallas", partitions=4,
                                            fault_plan=fp))
    raw = plan.run_raw(g)
    assert np.array_equal(raw, base)
    assert plan.backend == "xla"  # the ladder demoted the partitioned run
    assert plan.degradation and plan.degradation[0]["rung"] == "pallas->xla"


def test_partition_composes_with_reorder():
    g = _graph(23)
    base = compile(g, ALL_OPS, EngineConfig(backend="xla")).run_raw(g)
    for reorder in ("degree", "bfs", "rcm"):
        cfg = EngineConfig(backend="xla", partitions=4, reorder=reorder)
        plan = compile(g, ALL_OPS, cfg)
        assert np.array_equal(plan.run_raw(g), base), reorder


# ----------------------------------------------------------------------------
# config validation, locality guard, metadata surfacing
# ----------------------------------------------------------------------------

def test_partition_config_validation_messages():
    with pytest.raises(ValueError, match="partitions must be an int >= 1"):
        EngineConfig(partitions=0)
    with pytest.raises(ValueError, match="partitions must be an int >= 1"):
        EngineConfig(partitions=2.5)
    with pytest.raises(ValueError, match="spill must be None, a bool"):
        EngineConfig(spill=3)
    with pytest.raises(ValueError, match="device-resident path"):
        EngineConfig(partitions=2, device_accum=False)
    # inert spellings normalize into the same cached plan
    g = _graph(27, n=16, m=40)
    assert compile(g, ("triad_census",), EngineConfig(partitions=None)) is \
        compile(g, ("triad_census",), EngineConfig(partitions=1, spill=False))


def test_partition_rejects_nonlocal_ops():
    from repro.engine.ops import GraphOp, register_op

    class NonLocal(GraphOp):
        name = "nonlocal_probe"
        bins = 1
        kernel_key = "triad_census"
        delta_local = False

        def finalize(self, raw, g):
            return int(raw.sum())

    register_op(NonLocal(), overwrite=True)
    g = _graph(29, n=16, m=40)
    with pytest.raises(ValueError, match="delta_local"):
        compile(g, ("nonlocal_probe",), EngineConfig(partitions=2))
    compile(g, ("nonlocal_probe",), EngineConfig(partitions=1))  # fine


def test_partition_metadata_in_plan_cache_stats():
    g = _graph(31)
    plan = compile(g, ("triad_census",),
                   EngineConfig(backend="xla", partitions=4))
    plan.run(g)
    plan.run(g)  # warm: the layout memo must hit
    entry = plan_cache_stats()["entries"][-1]
    assert entry["partitions"] == 4
    assert entry["partition_memo"] == 1
    assert sum(entry["partition"]["shard_dyads"]) == g.n_dyads
    assert len(entry["partition"]["halo_sizes"]) == 4
    unpart = compile(g, ("dyad_census",), EngineConfig(backend="xla"))
    unpart.run(g)
    entry0 = plan_cache_stats()["entries"][-1]
    assert entry0["partitions"] == 1 and "partition" not in entry0


def test_partition_metadata_in_service_stats():
    svc = CensusService(ServiceConfig(
        max_batch=2, max_wait_requests=100,
        census=EngineConfig(backend="xla", partitions=2)))
    fleet = [generators.rmat(5, edge_factor=4, seed=s) for s in range(2)]
    for g in fleet:
        svc.submit(g)
    done = svc.flush()
    assert all(c.error is None for c in done)
    st = svc.stats()
    bucket = next(iter(st["buckets"].values()))
    assert bucket["partitions"] == 2
    assert sum(bucket["partition"]["shard_dyads"]) > 0


# ----------------------------------------------------------------------------
# the sharding.rules move (seed-era sharding/partition.py is a shim)
# ----------------------------------------------------------------------------

def test_sharding_partition_shim_warns_and_reexports():
    from repro.sharding import rules
    with pytest.warns(DeprecationWarning, match="repro.sharding.rules"):
        import repro.sharding.partition as shim
        importlib.reload(shim)
    assert shim.Rules is rules.Rules
    assert shim.make_rules is rules.make_rules
    assert shim.batch_axes is rules.batch_axes
    assert shim.constrain is rules.constrain
    from repro.sharding import Rules as pkg_rules
    assert pkg_rules is rules.Rules


# ----------------------------------------------------------------------------
# the real pool: partitions=8 over 8 forced host devices in a subprocess
# ----------------------------------------------------------------------------

def test_partitioned_run_over_forced_device_pool():
    code = """
import numpy as np, jax
assert len(jax.devices()) == 8
from repro.core import brute_force_census, generators
from repro.engine import EngineConfig, compile
g = generators.rmat(7, edge_factor=4, seed=11)
want = brute_force_census(g).counts
base = compile(g, ("triad_census",), EngineConfig(backend="xla")).run_raw(g)
for backend in ("xla", "distributed"):
    cfg = EngineConfig(backend=backend, partitions=8, batch=16,
                       chunk_dyads=16, schedule="dynamic")
    plan = compile(g, ("triad_census",), cfg)
    s0 = plan.stats["host_syncs"]
    raw = plan.run_raw(g)
    assert plan.stats["host_syncs"] - s0 == 1, backend
    assert np.array_equal(raw, base), backend
    assert (plan.run(g)["triad_census"].counts == want).all()
    if backend == "xla":
        assert plan.executor.n_devices == 8
        assert len(plan.stats["device_chunks"]) > 1  # pool fanned out
print('OK')
"""
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": SRC}
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


# ----------------------------------------------------------------------------
# partition_mode: validation, cache-key normalization, explicit-mode identity
# ----------------------------------------------------------------------------

def test_partition_mode_validation_messages():
    with pytest.raises(ValueError, match=r"'serial', 'pool', 'mesh'"):
        EngineConfig(partitions=2, partition_mode="parallel")
    with pytest.raises(ValueError, match="requires partitions > 1"):
        EngineConfig(partition_mode="pool")
    with pytest.raises(ValueError, match="requires partitions > 1"):
        EngineConfig(partitions=1, partition_mode="serial")
    g = _graph(33, n=16, m=40)
    with pytest.raises(ValueError, match="mesh"):
        compile(g, ("triad_census",),
                EngineConfig(backend="xla", partitions=2,
                             partition_mode="mesh"))
    with pytest.raises(ValueError, match="pool"):
        compile(g, ("triad_census",),
                EngineConfig(backend="distributed", partitions=2,
                             partition_mode="pool"))


def test_partition_mode_cache_key_normalization():
    g = _graph(35, n=16, m=40)
    # None resolves to the backend default and shares its plan entry
    default = compile(g, ("triad_census",),
                      EngineConfig(backend="xla", partitions=2))
    explicit = compile(g, ("triad_census",),
                       EngineConfig(backend="xla", partitions=2,
                                    partition_mode="pool"))
    assert default is explicit
    assert default.partition_mode == "pool"
    # a different mode is a different plan
    serial = compile(g, ("triad_census",),
                     EngineConfig(backend="xla", partitions=2,
                                  partition_mode="serial"))
    assert serial is not default
    assert serial.partition_mode == "serial"
    # spill defaults the mode to serial (one resident shard at a time)
    spilled = compile(g, ("triad_census",),
                      EngineConfig(backend="xla", partitions=2, spill=True))
    assert spilled.partition_mode == "serial"
    entry = plan_cache_stats()["entries"][-1]
    assert entry["partition_mode"] == "serial"


@pytest.mark.parametrize("backend", BACKENDS)
def test_partition_mode_explicit_bit_identity(backend):
    g = _graph(37, n=40, m=260)
    base = compile(g, ALL_OPS, EngineConfig(backend=backend)).run_raw(g)
    modes = (("mesh", "serial") if backend == "distributed"
             else ("pool", "serial"))
    for mode in modes:
        plan = compile(g, ALL_OPS,
                       EngineConfig(backend=backend, partitions=4,
                                    partition_mode=mode))
        s0 = plan.stats["host_syncs"]
        raw = plan.run_raw(g)
        assert np.array_equal(raw, base), (backend, mode)
        assert plan.stats["host_syncs"] - s0 == 1
        ps = plan.stats["partition"]
        assert ps["mode"] == mode


def test_partition_staging_hoisted_once_per_shard():
    # satellite regression: context staging happens exactly ONCE per
    # non-empty shard — never per chunk, never per worker — on both the
    # serial rung and the (single-device degenerate) pool.
    g = _graph(39, n=48, m=300)
    for mode in ("serial", "pool"):
        plan = compile(g, ("triad_census",),
                       EngineConfig(backend="xla", partitions=4,
                                    chunk_dyads=16, partition_mode=mode))
        plan.run(g)
        ps = plan.stats["partition"]
        nonempty = sum(1 for d in ps["shard_dyads"] if d)
        assert ps["h2d_puts"] == nonempty, (mode, ps["h2d_puts"], nonempty)
        assert set(ps["shard_times"]) == {
            s for s, d in enumerate(ps["shard_dyads"]) if d}
        for t in ps["shard_times"].values():
            assert t["end"] >= t["start"] and t["tasks"] >= 1
        assert 0.0 <= ps["shard_overlap"] <= 1.0
        # chunks dispatched == chunks folded, per device
        assert (sum(plan.stats["device_chunks"].values())
                == plan.stats["chunks"])


def test_partition_observables_in_plan_cache_stats():
    g = _graph(41)
    plan = compile(g, ("triad_census",),
                   EngineConfig(backend="xla", partitions=4))
    plan.run(g)
    entry = plan_cache_stats()["entries"][-1]
    ps = entry["partition"]
    assert ps["mode"] == entry["partition_mode"]
    for key in ("h2d_puts", "d2d_puts", "max_shard_bytes",
                "shard_overlap", "shard_times"):
        assert key in ps, key
    from repro.engine.partition import full_context_bytes
    # pow2 bucket rounding can equalize them on tiny graphs; the strict
    # ~P-fold drop is pinned by the benchmark on a locality-rich graph.
    assert 0 < ps["max_shard_bytes"] <= full_context_bytes(plan)


# ----------------------------------------------------------------------------
# device-side halo exchange: routing metadata + assembled-array identity
# ----------------------------------------------------------------------------

def test_halo_by_owner_groups_are_owner_contiguous():
    from repro.core.partition import halo_by_owner
    g = _graph(43, n=64, m=400)
    part = partition_graph(g, 4)
    for shard in part.shards:
        groups = halo_by_owner(part.cuts, shard.halo)
        rebuilt = np.concatenate([ids for _, ids in groups]) if groups \
            else np.empty(0, dtype=np.int64)
        assert np.array_equal(rebuilt, shard.halo)  # nothing lost/reordered
        owners = [o for o, _ in groups]
        assert owners == sorted(set(owners))  # one contiguous run per owner
        for o, ids in groups:
            assert o != shard.index  # halo rows are remote by construction
            lo, hi = int(part.cuts[o]), int(part.cuts[o + 1])
            assert ((ids >= lo) & (ids < hi)).all()


def test_pool_staging_assembles_exact_local_arrays():
    # the pool path's device-assembled shard context (ptr staging + owned
    # block scatter + per-owner halo exchange) must equal the host-built
    # serial context BIT FOR BIT — this is what makes pool/serial/p1
    # interchangeable.
    from repro.engine.partition import (_Geometry, _exchange_halos,
                                        _finish_pool_context, _shard_arrays,
                                        _stage_pool_shard, plan_partition)
    g = _graph(45, n=64, m=400)
    for backend in ("xla", "pallas"):
        plan = compile(g, ("triad_census",),
                       EngineConfig(backend=backend, partitions=4,
                                    partition_mode="pool"))
        part = plan_partition(plan, g)
        geom = _Geometry(plan, part)
        dev = plan.executor.devices[0]
        pstats = {"d2d_puts": 0}
        work = {}
        for shard in part.shards:
            if shard.n_dyads == 0:
                continue
            u, v = shard_dyads(g, shard.lo, shard.hi)
            work[shard.index] = _stage_pool_shard(plan, g, shard, geom,
                                                  u, v, dev)
        _exchange_halos(plan, g, part, work, pstats)
        for s, w in work.items():
            arrays, _n, _du, _dv = _finish_pool_context(plan, w)
            want = _shard_arrays(plan, g, part.shards[s], geom)
            for field in ("out_ptr", "out_idx", "nbr_ptr", "nbr_idx",
                          "nbr_deg", "in_ptr", "in_idx"):
                a, b = getattr(arrays, field), getattr(want, field)
                if b is None:
                    assert a is None, (backend, field)
                    continue
                assert np.array_equal(np.asarray(a), np.asarray(b)), \
                    (backend, s, field)


# ----------------------------------------------------------------------------
# concurrent pool over 8 forced host devices (subprocess)
# ----------------------------------------------------------------------------

def test_concurrent_pool_over_forced_device_pool():
    code = """
import numpy as np, jax
assert len(jax.devices()) == 8
from repro.core import brute_force_census, generators
from repro.engine import EngineConfig, FaultPlan, compile
g = generators.rmat(7, edge_factor=4, seed=11)
want = brute_force_census(g).counts
base = compile(g, ("triad_census",), EngineConfig(backend="xla")).run_raw(g)
# concurrent residency: every shard staged once, halos exchanged
# device-to-device, >= 2 shards in flight at once, one sync.
plan = compile(g, ("triad_census",),
               EngineConfig(backend="xla", partitions=8, batch=16,
                            chunk_dyads=16, schedule="dynamic"))
assert plan.partition_mode == "pool"
s0 = plan.stats["host_syncs"]
raw = plan.run_raw(g)
assert plan.stats["host_syncs"] - s0 == 1
assert np.array_equal(raw, base)
ps = plan.stats["partition"]
nonempty = sum(1 for d in ps["shard_dyads"] if d)
assert ps["mode"] == "pool"
assert ps["h2d_puts"] == nonempty, ps
assert ps["d2d_puts"] > 0, ps
assert ps["shard_overlap"] > 0.0, ps
assert len(plan.stats["device_chunks"]) > 1
assert sum(plan.stats["device_chunks"].values()) == plan.stats["chunks"]
# device loss mid-run: the dead home's shards re-home onto survivors,
# their contexts re-stage, and the result stays bit-identical in one
# sync.  The loss is a thread race (the dead worker must win a task),
# so re-run the warm plan until it lands.
lossy = compile(g, ("triad_census",),
                EngineConfig(backend="xla", partitions=8, batch=16,
                             chunk_dyads=16, schedule="dynamic",
                             fault_plan=FaultPlan(seed=5,
                                                  device_loss=(3,))))
runs = 0
for _ in range(8):
    raw = lossy.run_raw(g)
    runs += 1
    assert np.array_equal(raw, base)
    if lossy.stats["faults"]["device_losses"]:
        break
fs = lossy.stats["faults"]
assert fs["device_losses"] >= 1 and fs["quarantines"] >= 1, fs
assert lossy.stats["partition"].get("rehomes", 0) >= 1
assert lossy.stats["host_syncs"] == runs
print('OK')
"""
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": SRC}
    env.pop("REPRO_FAULT_PLAN", None)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
