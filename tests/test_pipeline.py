"""Device-resident streaming pipeline: enumeration, async double-buffered
dispatch, on-device hi/lo accumulation, LRU plan cache, deprecation shims."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import brute_force_census, from_edges, generators
from repro.core.census import canonical_dyads, enumerate_dyads_device
from repro.engine import (CensusConfig, compile_census, clear_plan_cache,
                          plan_cache_stats, set_plan_cache_capacity)

BACKENDS = ["xla", "pallas", "distributed"]


@pytest.fixture(autouse=True)
def _fresh_cache():
    clear_plan_cache()
    yield
    clear_plan_cache()
    set_plan_cache_capacity(32)


def _star(n):
    return from_edges(n, [0] * (n - 1), list(range(1, n)))


def _complete(n):
    src, dst = zip(*[(i, j) for i in range(n) for j in range(n) if i != j])
    return from_edges(n, src, dst)


# ----------------------------------------------------------------------------
# (a) device-enumerated dyads == host canonical_dyads
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("g", [
    generators.rmat(6, edge_factor=4, seed=0),
    generators.rmat(7, edge_factor=2, seed=5),
    _star(9),
    _complete(7),
], ids=["rmat6", "rmat7", "star", "complete"])
def test_device_enumeration_matches_host(g):
    plan = compile_census(g, CensusConfig(backend="xla", batch=16))
    arrays = plan.padded_arrays(g)
    du, dv = enumerate_dyads_device(arrays.nbr_ptr, arrays.nbr_idx,
                                    jnp.int32(g.m_nbr),
                                    out_size=plan.dyad_pad)
    hu, hv = canonical_dyads(g)
    du, dv = np.asarray(du), np.asarray(dv)
    d = g.n_dyads
    assert len(hu) == d
    # same dyads in the same (CSR row-major) order — bit-identical
    assert (du[:d] == hu).all() and (dv[:d] == hv).all()
    # padding past the true dyad count is the inert (0, 1) dyad
    assert (du[d:] == 0).all() and (dv[d:] == 1).all()


def test_device_enumeration_empty_graph():
    g = from_edges(6, [], [])
    plan = compile_census(g, CensusConfig(backend="xla"))
    arrays = plan.padded_arrays(g)
    du, dv = enumerate_dyads_device(arrays.nbr_ptr, arrays.nbr_idx,
                                    jnp.int32(0), out_size=plan.dyad_pad)
    assert (np.asarray(du) == 0).all() and (np.asarray(dv) == 1).all()
    res = plan.run(g)
    assert res.counts[0] == 6 * 5 * 4 // 6 and res.counts[1:].sum() == 0


# ----------------------------------------------------------------------------
# (b) async double-buffered path == synchronous path, bit-identical
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_device_path_matches_sync_baseline(backend):
    g = generators.rmat(7, edge_factor=4, seed=3)
    dev = compile_census(g, CensusConfig(backend=backend, batch=16,
                                         chunk_dyads=64))
    syn = compile_census(g, CensusConfig(backend=backend, batch=16,
                                         chunk_dyads=64, device_accum=False))
    assert dev is not syn  # device_accum is part of the plan key
    a = dev.run(g)
    b = syn.run(g)
    assert (a.counts == b.counts).all()
    assert (a.counts == brute_force_census(g).counts).all()
    # the O(chunks) -> O(1) sync claim: the sync baseline transfers once
    # per chunk; the device path exactly once per run on every backend
    # (the pallas bucket schedule is host-derived — no control fetch).
    assert syn.stats["host_syncs"] == syn.stats["chunks"] > 1
    assert dev.stats["host_syncs"] == 1


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("depth", [1, 4])
def test_pipeline_depth_invariant(backend, depth):
    """Results are bit-identical at any double-buffering depth."""
    g = generators.rmat(6, edge_factor=4, seed=1)
    base = compile_census(g, CensusConfig(backend=backend, batch=16,
                                          chunk_dyads=48))
    var = compile_census(g, CensusConfig(backend=backend, batch=16,
                                         chunk_dyads=48,
                                         pipeline_depth=depth))
    assert (base.run(g).counts == var.run(g).counts).all()


def test_device_path_is_default():
    g = generators.rmat(6, edge_factor=4, seed=0)
    plan = compile_census(g, CensusConfig(backend="xla"))
    assert plan.device_path
    plan.run(g)
    assert plan.stats["host_syncs"] == 1


def test_device_accum_none_normalizes_to_true_in_cache_key():
    g = generators.rmat(6, edge_factor=4, seed=0)
    a = compile_census(g, CensusConfig(backend="xla"))
    b = compile_census(g, CensusConfig(backend="xla", device_accum=True))
    assert a is b and plan_cache_stats()["misses"] == 1


# ----------------------------------------------------------------------------
# (c) on-device accumulator vs host int64 on int32-overflowing counts
# ----------------------------------------------------------------------------

def _overflow_graph():
    """8500 disjoint directed edges over 2**18 vertices: every canonical
    dyad contributes ~n dyadic (type 012) triads, so the total census count
    8500 * (n - 2) ~ 2.23e9 exceeds int32 — a plain int32 on-device
    accumulator would wrap."""
    n = 1 << 18
    src = np.arange(0, 17000, 2, dtype=np.int64)
    dst = src + 1
    return from_edges(n, src, dst), 8500 * (n - 2)


@pytest.mark.parametrize("backend", BACKENDS)
def test_device_accumulator_survives_int32_overflow(backend):
    g, expect_012 = _overflow_graph()
    assert expect_012 > np.iinfo(np.int32).max  # engineered overflow
    cfg = dict(backend=backend, chunk_dyads=2048)
    dev = compile_census(g, CensusConfig(**cfg))
    syn = compile_census(g, CensusConfig(**cfg, device_accum=False))
    got = dev.run(g).counts
    want = syn.run(g).counts  # host-side int64 accumulation: ground truth
    assert (got == want).all(), (got, want)
    assert got[1] == expect_012
    assert dev.stats["chunks"] > 1  # overflow spans chunk boundaries


# ----------------------------------------------------------------------------
# bounded LRU plan cache
# ----------------------------------------------------------------------------

def test_plan_cache_lru_eviction():
    g = generators.rmat(6, edge_factor=4, seed=0)
    set_plan_cache_capacity(2)
    p16 = compile_census(g, CensusConfig(backend="xla", batch=16))
    p32 = compile_census(g, CensusConfig(backend="xla", batch=32))
    assert plan_cache_stats()["evictions"] == 0
    # touch p16 so batch=32 is the LRU entry, then overflow the cache
    assert compile_census(g, CensusConfig(backend="xla", batch=16)) is p16
    compile_census(g, CensusConfig(backend="xla", batch=64))
    st = plan_cache_stats()
    assert st["size"] == 2 and st["evictions"] == 1 and st["capacity"] == 2
    # the recently-used plan survived; the LRU one was evicted
    assert compile_census(g, CensusConfig(backend="xla", batch=16)) is p16
    assert compile_census(g, CensusConfig(backend="xla", batch=32)) is not p32


def test_plan_cache_capacity_shrink_evicts():
    g = generators.rmat(6, edge_factor=4, seed=0)
    for b in (16, 32, 64):
        compile_census(g, CensusConfig(backend="xla", batch=b))
    set_plan_cache_capacity(1)
    st = plan_cache_stats()
    assert st["size"] == 1 and st["evictions"] == 2
    with pytest.raises(ValueError):
        set_plan_cache_capacity(0)


# ----------------------------------------------------------------------------
# deprecated shims emit DeprecationWarning
# ----------------------------------------------------------------------------

def test_deprecated_shims_warn():
    from repro.core import distributed_triad_census, triad_census
    from repro.kernels.ops import triad_census_kernel

    g = generators.rmat(6, edge_factor=4, seed=0)
    want = brute_force_census(g).counts
    with pytest.warns(DeprecationWarning, match="triad_census is deprecated"):
        res = triad_census(g)
    assert (res.counts == want).all()
    with pytest.warns(DeprecationWarning, match="triad_census_kernel"):
        counts = triad_census_kernel(g, block=16, buckets=(16, 64))
    assert (counts == want).all()
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.warns(DeprecationWarning, match="distributed_triad_census"):
        res, _ = distributed_triad_census(g, mesh)
    assert (res.counts == want).all()
