"""Locality-aware reordering: the permutation-equivariance harness.

Core-layer checks (strategy permutations are bijective/deterministic,
``permute_graph`` builds an isomorphic bucket-stable graph, deltas
commute with relabeling), engine-level bit-identity of every registered
op under ``reorder=`` on all three backends × both schedules, a
hypothesis property test over RANDOM permutations (the invariance claim,
not just the shipped strategies), the vertex-indexed ``unpermute_raw``
hook, cross-feature interaction with ``Plan.apply_delta`` (deltas stay
in original ids) and ``FaultPlan`` recovery, the one-sync / zero-retrace
/ warm-zero-reorder-cost pins, the bounded reorder memo, cache-key
separation + config validation, and a forced-8-device subprocess run."""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (GraphDelta, apply_delta_csr, compute_permutation,
                        from_edges, generators, inverse_permutation,
                        locality_score, permute_graph)
from repro.core.graph import dense_adjacency
from repro.core.reorder import REORDER_STRATEGIES
from repro.engine import (EngineConfig, FaultPlan, GraphOp, clear_plan_cache,
                          compile, plan_cache_stats, register_op)
from repro.engine.ops import unregister_op

BACKENDS = ["xla", "pallas", "distributed"]
ALL_OPS = ("triad_census", "dyad_census", "degree_stats", "triadic_profile")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(autouse=True)
def _fresh_cache():
    clear_plan_cache()
    yield
    clear_plan_cache()


def _cfg(backend, **kw):
    kw.setdefault("batch", 16)
    kw.setdefault("chunk_dyads", 64)
    return EngineConfig(backend=backend, **kw)


def _assert_result_equal(got, want, ctx=""):
    assert type(got) is type(want), (ctx, got, want)
    for name, a, b in zip(type(got)._fields, got, want):
        if isinstance(a, np.ndarray):
            assert np.array_equal(a, b), (ctx, name, a, b)
        else:
            assert a == b, (ctx, name, a, b)


def _assert_results_equal(got, want, ctx=""):
    assert got.keys() == want.keys(), ctx
    for name in got:
        _assert_result_equal(got[name], want[name], f"{ctx}:{name}")


def _assert_same_graph(a, b, ctx=""):
    for f in ("n", "m", "m_nbr", "max_deg", "max_out_deg"):
        assert getattr(a, f) == getattr(b, f), (ctx, f)
    for f in ("out_ptr", "out_idx", "nbr_ptr", "nbr_idx", "nbr_deg"):
        assert np.array_equal(np.asarray(getattr(a.arrays, f)),
                              np.asarray(getattr(b.arrays, f))), (ctx, f)


# ----------------------------------------------------------------------------
# core layer: strategies, permute_graph, delta translation
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", REORDER_STRATEGIES)
def test_permutation_is_bijective_and_deterministic(strategy):
    g = generators.rmat(6, edge_factor=4, seed=0)
    perm = compute_permutation(g, strategy)
    assert perm.shape == (g.n,) and perm.dtype == np.int64
    assert np.array_equal(np.sort(perm), np.arange(g.n))  # bijection
    assert np.array_equal(perm, compute_permutation(g, strategy))
    inv = inverse_permutation(perm)
    assert np.array_equal(perm[inv], np.arange(g.n))
    assert np.array_equal(inv[perm], np.arange(g.n))


def test_compute_permutation_rejects_unknown_strategy():
    g = generators.rmat(4, edge_factor=2, seed=0)
    with pytest.raises(ValueError, match="degree"):
        compute_permutation(g, "zorder")


def test_degree_order_packs_hubs_first():
    g = generators.rmat(6, edge_factor=4, seed=1)
    perm = compute_permutation(g, "degree")
    deg = np.asarray(g.arrays.nbr_deg)[: g.n]
    # degree as a function of NEW id must be non-increasing
    deg_new = deg[inverse_permutation(perm)]
    assert (np.diff(deg_new) <= 0).all()


@pytest.mark.parametrize("strategy", ["bfs", "rcm"])
def test_locality_improves_on_shuffled_ring(strategy):
    # a ring with scrambled labels: worst-case locality that any
    # frontier/bandwidth order must repair by a wide margin.
    n = 64
    rng = np.random.default_rng(3)
    lab = rng.permutation(n).astype(np.int64)
    g = from_edges(n, lab[np.arange(n)], lab[(np.arange(n) + 1) % n])
    perm = compute_permutation(g, strategy)
    before = locality_score(g)
    after = locality_score(permute_graph(g, perm))
    assert after < before / 3, (strategy, before, after)


@pytest.mark.parametrize("strategy", REORDER_STRATEGIES)
def test_permute_graph_is_isomorphic_and_bucket_stable(strategy):
    g = generators.rmat(6, edge_factor=4, seed=2)
    perm = compute_permutation(g, strategy)
    gp = permute_graph(g, perm)
    # metadata (and hence every plan bucket) is invariant
    for f in ("n", "m", "m_nbr", "max_deg", "max_out_deg"):
        assert getattr(gp, f) == getattr(g, f), f
    a, ap = dense_adjacency(g), dense_adjacency(gp)
    assert np.array_equal(ap[np.ix_(perm, perm)], a)  # same digraph


def test_permute_graph_identity_and_bad_shape():
    g = generators.rmat(5, edge_factor=3, seed=0)
    same = permute_graph(g, np.arange(g.n))
    _assert_same_graph(same, g, "identity")
    with pytest.raises(ValueError, match="shape"):
        permute_graph(g, np.arange(g.n - 1))


def test_strategies_handle_edgeless_and_disconnected_graphs():
    edgeless = from_edges(5, [], [])
    two_comp = from_edges(8, [0, 1, 4, 5, 6], [1, 2, 5, 6, 4])
    for strategy in REORDER_STRATEGIES:
        for g in (edgeless, two_comp):
            perm = compute_permutation(g, strategy)
            assert np.array_equal(np.sort(perm), np.arange(g.n))
            permute_graph(g, perm)


def test_apply_delta_commutes_with_relabeling():
    g = generators.rmat(5, edge_factor=4, seed=4)
    rng = np.random.default_rng(5)
    perm = rng.permutation(g.n).astype(np.int64)
    d = GraphDelta(edges_added=rng.integers(0, g.n, size=(4, 2)),
                   edges_removed=[(1, 0), (0, 2)])
    lhs = apply_delta_csr(permute_graph(g, perm), d.permuted(perm))
    rhs = permute_graph(apply_delta_csr(g, d), perm)
    _assert_same_graph(lhs, rhs, "commute")


# ----------------------------------------------------------------------------
# engine: bit-identity across strategies × backends × schedules
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("strategy", REORDER_STRATEGIES)
def test_reordered_run_bit_identical_all_ops(backend, strategy):
    g = generators.rmat(6, edge_factor=4, seed=6)
    base = compile(g, ALL_OPS, _cfg(backend))
    plan = compile(g, ALL_OPS, _cfg(backend, reorder=strategy))
    assert np.array_equal(plan.run_raw(g), base.run_raw(g))
    got, want = plan.run(g), base.run(g)
    _assert_results_equal(got, want, f"{backend}:{strategy}")
    # and against the NumPy oracles
    from repro.engine import get_op
    for name in ALL_OPS:
        _assert_result_equal(got[name], get_op(name).reference(g),
                             f"{backend}:{strategy}:{name}:ref")


@pytest.mark.parametrize("backend", BACKENDS)
def test_reordered_dynamic_schedule_bit_identical(backend):
    g = generators.rmat(6, edge_factor=6, seed=7)
    base = compile(g, ALL_OPS, _cfg(backend))
    plan = compile(g, ALL_OPS, _cfg(backend, reorder="rcm",
                                    schedule="dynamic"))
    _assert_results_equal(plan.run(g), base.run(g), f"{backend}:dynamic")


def test_random_permutation_equivariance_seeded():
    # always-on random-permutation coverage (the hypothesis variant below
    # skips when the library is absent): 10 seeded arbitrary relabelings,
    # raw bins and every op result bit-identical on all of them.
    g = generators.rmat(5, edge_factor=3, seed=23)
    plan = compile(g, ALL_OPS, _cfg("xla"))
    want, raw_want = plan.run(g), plan.run_raw(g)
    rng = np.random.default_rng(24)
    for trial in range(10):
        gp = permute_graph(g, rng.permutation(g.n).astype(np.int64))
        assert np.array_equal(plan.run_raw(gp), raw_want), trial
        _assert_results_equal(plan.run(gp), want, f"trial{trial}")


def test_random_permutation_equivariance_property():
    # the headline invariance, for ARBITRARY permutations: every
    # registered op's result is identical on any relabeling of the graph
    # (results are vertex-anonymous aggregates; bit-identity comes from
    # exact integer accumulation).
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    g = generators.rmat(5, edge_factor=3, seed=8)
    plan = compile(g, ALL_OPS, _cfg("xla"))
    want = plan.run(g)
    raw_want = plan.run_raw(g)

    @settings(max_examples=20, deadline=None)
    @given(st.permutations(range(g.n)))
    def prop(perm):
        gp = permute_graph(g, np.asarray(perm, dtype=np.int64))
        assert np.array_equal(plan.run_raw(gp), raw_want)
        _assert_results_equal(plan.run(gp), want, "random-perm")

    prop()


def test_reordered_run_batch_matches_member_runs():
    g1 = generators.rmat(6, edge_factor=4, seed=9)
    g2 = apply_delta_csr(g1, GraphDelta(edges_added=[(0, 3), (9, 2)]))
    base = compile(g1, ALL_OPS, _cfg("xla"))
    plan = compile(g1, ALL_OPS, _cfg("xla", reorder="degree"))
    got = plan.run_batch([g1, g2])
    for res, g in zip(got, (g1, g2)):
        _assert_results_equal(res, base.run(g), "batch")


# ----------------------------------------------------------------------------
# the unpermute hook: vertex-indexed raw bins
# ----------------------------------------------------------------------------

class _VertexOutDegOp(GraphOp):
    """Test-only op whose raw slice is VERTEX-INDEXED (bin i = out-degree
    of vertex i) — exercises the inverse-permutation hook that aggregate
    built-ins never need."""

    name = "_vertex_outdeg"
    bins = 32

    def make_once_fn(self, meta, config):
        B = self.bins

        def once(arrays, n):
            nb = arrays.out_ptr.shape[0] - 1
            deg = (arrays.out_ptr[1:] - arrays.out_ptr[:-1]).astype(
                config.acc_jnp_dtype)
            deg = jnp.where(jnp.arange(nb) < n, deg, 0)
            return jnp.zeros(B, config.acc_jnp_dtype).at[:nb].add(deg[:B])

        return once

    def finalize(self, raw, g):
        return np.asarray(raw[: g.n], dtype=np.int64)

    def unpermute_raw(self, raw, perm, g):
        out = np.array(raw, dtype=np.int64)
        out[: g.n] = raw[np.asarray(perm)]
        return out

    def reference(self, g):
        return np.diff(np.asarray(g.arrays.out_ptr)[: g.n + 1]).astype(
            np.int64)


@pytest.fixture
def _vertex_op():
    op = register_op(_VertexOutDegOp(), overwrite=True)
    yield op
    unregister_op(op.name)


def test_vertex_indexed_op_unpermutes_raw_bins(_vertex_op):
    g = generators.rmat(5, edge_factor=4, seed=10)  # n = 32 = op.bins
    ops = ("triad_census", _vertex_op.name)
    base = compile(g, ops, _cfg("xla"))
    want_raw = base.run_raw(g)
    for strategy in REORDER_STRATEGIES:
        plan = compile(g, ops, _cfg("xla", reorder=strategy))
        # raw contract: ORIGINAL vertex space, regardless of reorder
        assert np.array_equal(plan.run_raw(g), want_raw), strategy
        got = plan.run(g)
        assert np.array_equal(got[_vertex_op.name],
                              _vertex_op.reference(g)), strategy
        _assert_result_equal(got["triad_census"],
                             base.run(g)["triad_census"], strategy)


def test_vertex_indexed_op_through_delta(_vertex_op):
    g = generators.rmat(5, edge_factor=4, seed=11)
    ops = ("triad_census", _vertex_op.name)
    plan = compile(g, ops, _cfg("xla", reorder="rcm", delta_threshold=1.0))
    raw = plan.run_raw(g)
    res = plan.apply_delta(g, GraphDelta(edges_added=[(0, 7), (3, 9)],
                                         edges_removed=[(1, 0)]), raw)
    assert res.mode == "delta"
    assert np.array_equal(res.results[_vertex_op.name],
                          _vertex_op.reference(res.graph))
    base = compile(g, ops, _cfg("xla"))
    assert np.array_equal(res.raw, base.run_raw(res.graph))


# ----------------------------------------------------------------------------
# cross-feature: deltas in original ids, fault recovery
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_reorder_delta_bit_identical_to_full_recompute(backend):
    g = generators.rmat(6, edge_factor=4, seed=12)
    plan = compile(g, ALL_OPS, _cfg(backend, reorder="rcm",
                                    delta_threshold=1.0))
    base = compile(g, ALL_OPS, _cfg(backend))
    raw = plan.run_raw(g)
    rng = np.random.default_rng(13)
    d = GraphDelta(edges_added=rng.integers(0, g.n, size=(4, 2)),
                   edges_removed=[(1, 0)])
    res = plan.apply_delta(g, d, raw)  # delta in ORIGINAL vertex ids
    assert res.mode == "delta", backend
    _assert_same_graph(res.graph, apply_delta_csr(g, d), backend)
    assert np.array_equal(res.raw, base.run_raw(res.graph)), backend
    _assert_results_equal(res.results, base.run(res.graph), backend)


def test_reorder_soak_mutation_stream():
    # deterministic 12-step soak mirroring test_delta's: a reordered
    # plan's delta stream stays bit-identical to plain full recomputes,
    # pays ONE permutation for the whole stream, and one sync per step.
    g = generators.rmat(6, edge_factor=4, seed=14)
    plan = compile(g, ALL_OPS, _cfg("xla", reorder="bfs",
                                    delta_threshold=1.0))
    base = compile(g, ALL_OPS, _cfg("xla"))
    raw = plan.run_raw(g)
    rng = np.random.default_rng(15)
    for step in range(12):
        add = rng.integers(0, g.n, size=(2, 2))
        rem = rng.integers(0, g.n, size=(1, 2))
        before = plan.stats["host_syncs"]
        res = plan.apply_delta(g, GraphDelta(edges_added=add,
                                             edges_removed=rem), raw)
        if res.mode == "delta":
            assert plan.stats["host_syncs"] - before == 1, step
        assert np.array_equal(res.raw, base.run_raw(res.graph)), step
        g, raw = res.graph, res.raw
    assert plan.stats["reorders"] == 1  # one permutation for 12 mutations


def test_reorder_fault_recovery_bit_identical():
    g = generators.rmat(6, edge_factor=6, seed=16)
    want = compile(g, ALL_OPS, _cfg("xla")).run(g)
    plan = compile(g, ALL_OPS, _cfg(
        "xla", reorder="rcm",
        fault_plan=FaultPlan(seed=3, chunk_failure_rate=0.5,
                             fail_attempts=1)))
    before = plan.stats["host_syncs"]
    _assert_results_equal(plan.run(g), want, "faulty-reordered")
    assert plan.stats["faults"]["retries"] > 0
    assert plan.stats["host_syncs"] - before == 1


def test_reorder_fault_recovery_dynamic_device_loss():
    g = generators.rmat(6, edge_factor=6, seed=17)
    want = compile(g, ALL_OPS, _cfg("xla")).run(g)
    plan = compile(g, ALL_OPS, _cfg(
        "xla", reorder="degree", schedule="dynamic",
        fault_plan=FaultPlan(seed=4, chunk_failure_rate=0.3,
                             fail_attempts=1, device_loss=(1,))))
    _assert_results_equal(plan.run(g), want, "device-loss-reordered")
    assert plan.stats["faults"]["retries"] > 0


# ----------------------------------------------------------------------------
# regression pins: syncs, retraces, warm reorder cost, bounded memo
# ----------------------------------------------------------------------------

def test_reordered_one_sync_zero_retrace_zero_rereorder_warm():
    g1 = generators.rmat(6, edge_factor=4, seed=18)
    g2 = apply_delta_csr(g1, GraphDelta(edges_added=[(0, 5)]))  # same bucket
    plan = compile(g1, ALL_OPS, _cfg("xla", reorder="rcm"))
    plan.run(g1)  # cold: trace + permutation
    traces = plan.stats["traces"]
    assert plan.stats["reorders"] == 1
    before = plan.stats["host_syncs"]
    plan.run(g1)  # warm same graph: no retrace, no re-permute, one sync
    assert plan.stats["host_syncs"] - before == 1
    assert plan.stats["traces"] == traces
    assert plan.stats["reorders"] == 1
    before = plan.stats["host_syncs"]
    plan.run(g2)  # warm same-bucket graph: new permutation, same trace
    assert plan.stats["host_syncs"] - before == 1
    assert plan.stats["traces"] == traces
    assert plan.stats["reorders"] == 2


def test_reorder_memo_bounded_surfaced_and_cleared():
    g = generators.rmat(5, edge_factor=3, seed=19)
    plan = compile(g, ("triad_census",), _cfg("xla", reorder="degree"))
    # 12 distinct same-bucket graphs: drop one different arc each (removal
    # can never outgrow the plan's metadata buckets)
    from repro.core import arcs_host
    src, dst = arcs_host(g)
    graphs = [g] + [
        apply_delta_csr(g, GraphDelta(edges_removed=[(src[i], dst[i])]))
        for i in range(11)]
    for gi in graphs:
        plan.run(gi)
    assert 0 < len(plan._reorder_memo) <= 8  # bounded
    entry = plan_cache_stats()["entries"][-1]
    assert entry["reorder"] == "degree"
    assert entry["reorder_memo"] == len(plan._reorder_memo)
    clear_plan_cache()
    assert len(plan._reorder_memo) == 0
    assert plan_cache_stats()["size"] == 0


# ----------------------------------------------------------------------------
# config validation + plan-cache key separation
# ----------------------------------------------------------------------------

def test_config_rejects_unknown_reorder_with_strategy_list():
    with pytest.raises(ValueError) as e:
        EngineConfig(reorder="hilbert")
    msg = str(e.value)
    for name in ("none", "degree", "bfs", "rcm"):
        assert name in msg


def test_reorder_is_part_of_plan_cache_key():
    g = generators.rmat(5, edge_factor=3, seed=21)
    plain = compile(g, ("triad_census",), _cfg("xla"))
    assert compile(g, ("triad_census",), _cfg("xla", reorder="none")) is plain
    plans = {s: compile(g, ("triad_census",), _cfg("xla", reorder=s))
             for s in REORDER_STRATEGIES}
    objs = [plain, *plans.values()]
    assert len({id(p) for p in objs}) == len(objs)  # no shared state
    assert plan_cache_stats()["size"] == len(objs)
    for s, p in plans.items():
        assert compile(g, ("triad_census",), _cfg("xla", reorder=s)) is p


# ----------------------------------------------------------------------------
# forced 8-device pool (subprocess)
# ----------------------------------------------------------------------------

def test_reorder_under_forced_device_pool():
    code = """
import numpy as np, jax
assert len(jax.devices()) == 8
from repro.core import GraphDelta, generators
from repro.engine import EngineConfig, compile
g = generators.rmat(7, edge_factor=4, seed=22)
ops = ("triad_census", "dyad_census", "degree_stats", "triadic_profile")
for backend in ("xla", "pallas"):
    base = compile(g, ops, EngineConfig(backend=backend, batch=16,
                                        chunk_dyads=64))
    plan = compile(g, ops, EngineConfig(backend=backend, batch=16,
                                        chunk_dyads=64, schedule="dynamic",
                                        reorder="rcm", delta_threshold=1.0))
    assert plan.executor.n_devices == 8
    assert np.array_equal(plan.run_raw(g), base.run_raw(g)), backend
    rng = np.random.default_rng(0)
    res = plan.apply_delta(g, GraphDelta(
        edges_added=rng.integers(0, g.n, size=(6, 2))), plan.run_raw(g))
    assert res.mode == "delta", backend
    assert np.array_equal(res.raw, base.run_raw(res.graph)), backend
print('OK')
"""
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": SRC}
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
