"""Executor layer: cost-model chunk boundaries, dynamic-vs-static
bit-identity for every registered op on all three backends, the
single-sync regression pin (the pallas control fetch is gone), config
knob validation, schedule metadata in the plan cache, per-device
occupancy counters, and a forced-8-device subprocess exercising the real
work-queue pool."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import balance, brute_force_census, from_edges, generators
from repro.core.census import host_bucket_schedule, sort_dyads_by_bucket
from repro.engine import (EngineConfig, clear_plan_cache, compile,
                          list_ops, plan_cache_stats)
from repro.serve import CensusService, ServiceConfig

BACKENDS = ["xla", "pallas", "distributed"]
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(autouse=True)
def _fresh_cache():
    clear_plan_cache()
    yield
    clear_plan_cache()


def _assert_result_equal(got, want, ctx=""):
    assert type(got) is type(want), (ctx, got, want)
    for name, a, b in zip(type(got)._fields, got, want):
        if isinstance(a, np.ndarray):
            assert np.array_equal(a, b), (ctx, name, a, b)
        else:
            assert a == b, (ctx, name, a, b)


# ----------------------------------------------------------------------------
# cost-model chunk boundaries (core/balance.py driving the executor)
# ----------------------------------------------------------------------------

def test_chunk_bounds_cover_and_respect_capacity():
    rng = np.random.default_rng(0)
    w = rng.integers(1, 50, size=1000).astype(np.float64)
    b = balance.chunk_bounds_by_cost(w, 128)
    assert b[0] == 0 and b[-1] == len(w)
    spans = np.diff(b)
    assert (spans >= 1).all() and (spans <= 128).all()
    # equal-cost property: no chunk's predicted work dominates the run
    costs = np.add.reduceat(w, b[:-1])
    assert costs.max() <= 2 * costs.mean()


def test_chunk_bounds_heavy_items_get_small_chunks():
    # a heavy-degree region in an otherwise light stream: its chunks must
    # be shorter than the light region's (the paper's degree-aware load
    # shaping, applied to the chunk schedule).
    w = np.concatenate([np.ones(400), np.full(100, 100.0), np.ones(400)])
    b = balance.chunk_bounds_by_cost(w, 256)
    spans = np.diff(b)
    mids = (b[:-1] + b[1:]) // 2
    heavy = spans[(mids >= 400) & (mids < 500)]
    light = spans[mids < 400]
    assert heavy.max() < light.min()
    # a single task heavier than the quota still gets a chunk of its own
    b2 = balance.chunk_bounds_by_cost(np.array([1.0, 1e9, 1.0]), 8)
    assert (np.diff(b2) >= 1).all() and b2[-1] == 3


def test_chunk_bounds_degenerate():
    assert balance.chunk_bounds_by_cost(np.zeros(0), 4).tolist() == [0]
    assert balance.chunk_bounds_by_cost(np.zeros(5), 2).tolist() == [0, 2, 4, 5]
    with pytest.raises(ValueError, match="capacity"):
        balance.chunk_bounds_by_cost(np.ones(3), 0)


def test_host_bucket_schedule_matches_device_sort():
    """The host-derived bucket counts (which replaced the pallas control
    fetch) must equal the device sort's histogram exactly — the chunk
    schedule slices the device-sorted stream by them."""
    import jax.numpy as jnp

    from repro.core.census import enumerate_dyads_device

    for seed in (0, 5):
        g = generators.rmat(6, edge_factor=4, seed=seed)
        ks = tuple(sorted({min(k, max(g.max_deg, 1)) for k in (4, 16, 64)}
                          | {max(g.max_deg, 1)}))
        du, dv = enumerate_dyads_device(g.arrays.nbr_ptr, g.arrays.nbr_idx,
                                        jnp.int32(g.m_nbr),
                                        out_size=max(g.n_dyads, 1))
        _, _, counts_dev = sort_dyads_by_bucket(
            g.arrays.nbr_deg, g.arrays.out_ptr, du, dv,
            jnp.int32(g.n_dyads), ks=ks)
        counts, need_sorted = host_bucket_schedule(g, ks)
        assert counts.tolist() == np.asarray(counts_dev).tolist()
        assert counts.sum() == g.n_dyads == len(need_sorted)
        assert (np.diff(need_sorted) >= 0).sum() >= 0  # grouped-by-bucket


# ----------------------------------------------------------------------------
# dynamic == static bit-identity, every registered op, every backend
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_dynamic_schedule_bit_identical(backend):
    """Acceptance criterion: the dynamic work-queue schedule (over
    however many devices this process sees — the multi-device CI job
    forces 8) produces exactly the static single-device results for
    every registered op."""
    ops = list_ops()
    g = generators.rmat(6, edge_factor=4, seed=2)
    stat = compile(g, ops, EngineConfig(backend=backend, batch=16,
                                        chunk_dyads=64))
    dyn = compile(g, ops, EngineConfig(backend=backend, batch=16,
                                       chunk_dyads=64, schedule="dynamic"))
    a, b = stat.run(g), dyn.run(g)
    for name in ops:
        _assert_result_equal(a[name], b[name], ctx=(backend, name))
    assert (b["triad_census"].counts == brute_force_census(g).counts).all()


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_dynamic_schedule_on_degree_skewed_graph(backend):
    """A star graph maximizes degree skew: the cost model must shrink
    chunks around the hub's dyads and the results must not move."""
    g = from_edges(40, [0] * 39 + list(range(1, 20)),
                   list(range(1, 40)) + [0] * 19)
    stat = compile(g, ("triad_census",),
                   EngineConfig(backend=backend, batch=16, chunk_dyads=32))
    dyn = compile(g, ("triad_census",),
                  EngineConfig(backend=backend, batch=16, chunk_dyads=32,
                               schedule="dynamic"))
    a = stat.run(g)["triad_census"]
    b = dyn.run(g)["triad_census"]
    assert (a.counts == b.counts).all()
    assert (a.counts == brute_force_census(g).counts).all()


def test_dynamic_batch_runs_bit_identical():
    fleet = [generators.rmat(6, edge_factor=4, seed=s) for s in (0, 1)]
    empty = from_edges(5, [], [])
    ops = ("triad_census", "degree_stats")
    dyn = compile(fleet[0], ops, EngineConfig(backend="xla", batch=16,
                                              chunk_dyads=64,
                                              schedule="dynamic"))
    batched = dyn.run_batch(fleet + [empty])
    for got, g in zip(batched, fleet + [empty]):
        want = dyn.run(g)
        for name in ops:
            _assert_result_equal(got[name], want[name], ctx=name)


# ----------------------------------------------------------------------------
# satellite: the pallas extra sync is gone — pin host_syncs == 1 everywhere
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_device_path_single_sync_regression_pin(backend):
    """Every backend's device-resident run costs exactly ONE counted
    device→host transfer.  The pallas backend used to pay 2 (a control
    fetch of the device sort's bucket counts — BENCH_census.json showed
    host_syncs_per_run: 2 while xla/distributed showed 1); the schedule
    is now derived host-side (census.py::host_bucket_schedule), so a
    regression reintroducing the fetch fails here."""
    g = generators.rmat(7, edge_factor=4, seed=3)
    for schedule in ("static", "dynamic"):
        plan = compile(g, ("triad_census",),
                       EngineConfig(backend=backend, batch=16,
                                    chunk_dyads=64, schedule=schedule))
        plan.run(g)
        runs = plan.stats["runs"]
        assert plan.stats["host_syncs"] == runs == 1, (backend, schedule,
                                                       plan.stats)
        plan.run(g)
        assert plan.stats["host_syncs"] == 2  # exactly one more per run


# ----------------------------------------------------------------------------
# satellite: EngineConfig numeric-knob validation
# ----------------------------------------------------------------------------

def test_numeric_knobs_validated_at_construction():
    with pytest.raises(ValueError, match="chunk_dyads must be >= 1"):
        EngineConfig(chunk_dyads=0)
    with pytest.raises(ValueError, match="chunk_dyads must be >= 1"):
        EngineConfig(chunk_dyads=-5)
    with pytest.raises(ValueError, match="pipeline_depth must be >= 1"):
        EngineConfig(pipeline_depth=0)
    with pytest.raises(ValueError, match="n_executor_devices must be >= 1"):
        EngineConfig(n_executor_devices=0)
    with pytest.raises(ValueError, match="n_executor_devices must be >= 1"):
        EngineConfig(n_executor_devices=-1)
    with pytest.raises(ValueError, match="schedule must be one of"):
        EngineConfig(schedule="adaptive")
    with pytest.raises(ValueError, match="batch must be >= 1"):
        EngineConfig(batch=0)
    with pytest.raises(ValueError, match="block must be >= 1"):
        EngineConfig(block=0)
    # the happy path stays hashable (the config is a plan-cache key)
    hash(EngineConfig(chunk_dyads=64, pipeline_depth=3,
                      schedule="dynamic", n_executor_devices=4))


# ----------------------------------------------------------------------------
# satellite: schedule metadata in the plan cache + device occupancy
# ----------------------------------------------------------------------------

def test_plan_cache_entries_carry_schedule_and_devices():
    import jax

    g = generators.rmat(6, edge_factor=4, seed=0)
    compile(g, ("triad_census",), EngineConfig(backend="xla", chunk_dyads=64))
    dyn = compile(g, ("triad_census",),
                  EngineConfig(backend="xla", chunk_dyads=64,
                               schedule="dynamic"))
    entries = plan_cache_stats()["entries"]
    assert [e["schedule"] for e in entries] == ["static", "dynamic"]
    assert entries[0]["n_devices"] == 1
    assert entries[1]["n_devices"] == len(jax.devices())
    # pool width asked beyond the visible device count is clamped, and
    # normalizes into the SAME cache entry as the all-devices default
    over = compile(g, ("triad_census",),
                   EngineConfig(backend="xla", chunk_dyads=64,
                                schedule="dynamic",
                                n_executor_devices=10_000))
    assert over is dyn
    assert over.executor.n_devices == len(jax.devices())


def test_device_chunk_occupancy_accounting():
    g = generators.rmat(6, edge_factor=4, seed=1)
    plan = compile(g, ("triad_census",),
                   EngineConfig(backend="xla", chunk_dyads=64,
                                schedule="dynamic"))
    plan.run(g)
    dc = plan.stats["device_chunks"]
    assert sum(dc.values()) == plan.stats["chunks"] > 0
    assert all(0 <= d < plan.executor.n_devices for d in dc)
    entry = plan_cache_stats()["entries"][0]
    assert entry["device_chunks"] == dc


def test_service_reports_per_device_occupancy():
    ops_sets = (("triad_census",), ("triad_census", "degree_stats"))
    svc = CensusService(ServiceConfig(
        max_batch=4, max_wait_requests=100,
        census=EngineConfig(backend="xla", batch=16, chunk_dyads=64,
                            schedule="dynamic")))
    fleet = [generators.rmat(6, edge_factor=4, seed=s) for s in range(4)]
    for i, g in enumerate(fleet):  # two (bucket, ops) groups
        svc.submit(g, ops=ops_sets[i % 2])
    done = svc.flush()
    assert len(done) == 4
    for c in done:
        _assert_result_equal(
            c.result["triad_census"] if isinstance(c.result, dict)
            else c.result,
            compile(fleet[c.request_id], ("triad_census",),
                    EngineConfig(backend="xla", batch=16, chunk_dyads=64)
                    ).run(fleet[c.request_id])["triad_census"])
    st = svc.stats()
    assert sum(st["devices"].values()) == sum(
        b["chunks"] for b in st["buckets"].values()) > 0


def test_service_static_schedule_keeps_device_zero():
    svc = CensusService(ServiceConfig(
        max_batch=2, census=EngineConfig(backend="xla", chunk_dyads=64)))
    svc.run_fleet([generators.rmat(6, edge_factor=4, seed=s)
                   for s in range(2)])
    st = svc.stats()
    assert set(st["devices"]) == {0}


# ----------------------------------------------------------------------------
# the real pool: forced 8 host devices in a subprocess (the flag must be
# set before jax initializes; the multi-device CI job runs the whole
# suite this way, this test guarantees coverage on 1-device hosts too)
# ----------------------------------------------------------------------------

def test_workqueue_spreads_over_forced_device_pool():
    code = """
import numpy as np, jax
assert len(jax.devices()) == 8
from repro.core import brute_force_census, generators
from repro.engine import EngineConfig, compile
g = generators.rmat(7, edge_factor=4, seed=11)
want = brute_force_census(g).counts
for backend in ("xla", "pallas"):
    dyn = compile(g, ("triad_census", "dyad_census"),
                  EngineConfig(backend=backend, batch=16, chunk_dyads=64,
                               schedule="dynamic"))
    res = dyn.run(g)
    assert (res["triad_census"].counts == want).all(), backend
    assert dyn.executor.n_devices == 8
    dc = dyn.stats["device_chunks"]
    assert sum(dc.values()) == dyn.stats["chunks"]
    assert len(dc) > 1, (backend, dc)  # the queue actually fanned out
    assert dyn.stats["host_syncs"] == 1  # one merged fetch, pool-wide
print('OK')
"""
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": SRC}
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
